"""Zero-gather decode plane: kernel parity vs the dense oracle, bounded jit
cache under ragged batches, fused batch append, and the satellite
regressions (kill_node block leak, fused prefill write, derived bandwidth
utilization)."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.cluster import PDCluster
from repro.serving.engine import NodeEngine, _next_pow2
from repro.serving.kv_cache import PagedKVCache, spec_for_model
from repro.serving.request import Request, SamplingParams


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n, seed=0, lo=5, hi=30):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, cfg.vocab_size, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _run_cluster(cfg, params, prompts, steps, *, paged_decode, allocator="flowkv"):
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, allocator=allocator,
                        paged_decode=paged_decode)
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=steps))
            for p in prompts]
    done = cluster.run(reqs, max_cycles=200)
    assert len(done) == len(prompts)
    return cluster, {tuple(r.prompt_tokens): list(r.output_tokens) for r in done}


# ---------------------------------------------------------------------------
# Tentpole: paged-kernel decode is token-identical to the dense oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("allocator", ["flowkv", "freelist"])
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_paged_decode_matches_dense_oracle(small_model, batch, allocator):
    """Ragged prompt lengths, both allocators, batch sizes 1/3/8."""
    cfg, params = small_model
    prompts = _prompts(cfg, batch, seed=batch, lo=3, hi=40)
    _, kernel = _run_cluster(cfg, params, prompts, 5,
                             paged_decode="kernel", allocator=allocator)
    _, dense = _run_cluster(cfg, params, prompts, 5,
                            paged_decode="dense", allocator=allocator)
    assert kernel == dense


def test_paged_decode_matches_monolithic_reference(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, 4, seed=11)
    cluster, outs = _run_cluster(cfg, params, prompts, 6, paged_decode="kernel")
    for p in prompts:
        ref = T.greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), 6)
        assert outs[tuple(p)] == [int(x) for x in ref[0]]
    # O(1) dispatches per decode cycle on the zero-gather path
    s = cluster.stats()
    assert s["decode_steps"] > 0
    assert s["mean_decode_dispatches_per_step"] == 1.0


def test_paged_decode_moe_family_parity():
    """The zero-gather step covers every paged family, not just dense."""
    cfg = get_smoke_config("granite-moe-1b-a400m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg, 3, seed=2, lo=4, hi=20)
    _, kernel = _run_cluster(cfg, params, prompts, 4, paged_decode="kernel")
    _, dense = _run_cluster(cfg, params, prompts, 4, paged_decode="dense")
    assert kernel == dense


def test_paged_step_is_single_dispatch_per_cycle(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, 6, seed=3)
    cluster, _ = _run_cluster(cfg, params, prompts, 5, paged_decode="kernel")
    d = cluster.engines[1]
    assert d.decode_dispatches == d.decode_steps
    for r in cluster.finished:
        assert r.decode_dispatches == r.decode_steps


def test_dense_oracle_pays_per_request_dispatches(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, 4, seed=5)
    cluster, _ = _run_cluster(cfg, params, prompts, 4, paged_decode="dense")
    d = cluster.engines[1]
    # every dense cycle costs 2B+1 dispatches; with B>=1 that's >= 3 per step
    assert d.decode_dispatches >= 3 * d.decode_steps


# ---------------------------------------------------------------------------
# Jit-cache bucketing: ragged workloads compile few variants
# ---------------------------------------------------------------------------
def test_bucketed_step_bounds_jit_cache(small_model):
    cfg, params = small_model
    max_batch, max_blocks = 8, 16
    engine = NodeEngine(0, cfg, params, num_blocks=128,
                        paged_decode="kernel", max_batch_tokens=8192)
    rng = np.random.RandomState(0)
    # ragged arrival: batches of every size 1..max_batch, ragged lengths
    for wave in range(1, max_batch + 1):
        reqs = [Request(prompt_tokens=list(rng.randint(0, cfg.vocab_size,
                                                       rng.randint(3, 60))),
                        sampling=SamplingParams(max_new_tokens=3))
                for _ in range(wave)]
        for r in reqs:
            engine.scheduler.enqueue_prefill(r)
        pending = list(reqs)
        while pending:
            done, _ = engine.step()
            for r in done:
                engine.scheduler.enqueue_decode(r)
                pending.remove(r)
        finished = []
        while len(finished) < len(reqs):
            _, fin = engine.step()
            finished.extend(fin)
    bound = math.log2(max_batch) * math.log2(max_blocks)
    assert 1 <= engine.decode_compile_variants <= bound, \
        (engine.decode_compile_variants, bound)
    # and every bucket is a power of two on both axes
    for bp, wp in engine._decode_cache_keys:
        assert bp == _next_pow2(bp) and wp == _next_pow2(wp)


def test_next_pow2():
    assert [_next_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [1, 2, 4, 4, 8, 8, 16]


# ---------------------------------------------------------------------------
# Fused batch append (kv_cache port over the descriptor-table kernel)
# ---------------------------------------------------------------------------
def test_append_tokens_matches_per_request_appends(small_model):
    cfg, params = small_model
    spec = spec_for_model(cfg, 32)
    a = PagedKVCache(spec, "flowkv")
    b = PagedKVCache(spec, "flowkv")
    L, KV, hd = spec.num_layers, spec.num_kv_heads, spec.head_dim
    rng = np.random.RandomState(0)
    positions = []
    for rid, ntok in ((0, 5), (1, spec.block_size), (2, 2 * spec.block_size - 1)):
        a.bm.allocate(rid, ntok + 1)
        b.bm._table[rid] = list(a.bm.get(rid))     # identical placement
        positions.append(ntok)                     # append lands after ntok
    kn = jnp.asarray(rng.randn(L, 3, KV, hd), spec.dtype)
    vn = jnp.asarray(rng.randn(L, 3, KV, hd), spec.dtype)
    a.append_tokens([0, 1, 2], kn, vn, positions)
    for i, rid in enumerate((0, 1, 2)):
        b.append_token(rid, kn[:, i], vn[:, i], positions[i])
    np.testing.assert_array_equal(np.asarray(a.pool, np.float32),
                                  np.asarray(b.pool, np.float32))
    assert a.num_pool_dispatches == 1              # one fused dispatch
    assert b.num_pool_dispatches == 3              # one per request


def test_write_prefill_single_fused_update_roundtrips(small_model):
    """Satellite: K and V land in one pool update, contents unchanged."""
    cfg, params = small_model
    spec = spec_for_model(cfg, 16)
    kv = PagedKVCache(spec, "flowkv")
    L, KV, hd = spec.num_layers, spec.num_kv_heads, spec.head_dim
    length = spec.block_size + 3                   # spans 2 blocks, padded tail
    kv.bm.allocate(7, length)
    rng = np.random.RandomState(1)
    k = jnp.asarray(rng.randn(L, length, KV, hd), spec.dtype)
    v = jnp.asarray(rng.randn(L, length, KV, hd), spec.dtype)
    kv.write_prefill(7, k, v, length)
    assert kv.num_pool_dispatches == 1
    kk, vv = kv.gather_dense(7, length)
    np.testing.assert_array_equal(np.asarray(kk, np.float32), np.asarray(k, np.float32))
    np.testing.assert_array_equal(np.asarray(vv, np.float32), np.asarray(v, np.float32))


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------
def test_kill_node_releases_paged_blocks(small_model):
    """kill_node used to clear .states but leak block-manager allocations."""
    cfg, params = small_model
    prompts = _prompts(cfg, 3, seed=9)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1, num_blocks=64)
    reqs = [Request(prompt_tokens=list(p), sampling=SamplingParams(max_new_tokens=8))
            for p in prompts]
    for r in reqs:
        cluster.submit(r)
    for _ in range(3):        # mid-flight: prefill node holds live blocks
        cluster.step()
    assert any(e.scheduler.bm.num_free < 64 for e in cluster.engines.values())
    for nid in list(cluster.engines):
        cluster.kill_node(nid)
        eng = cluster.engines[nid]
        assert eng.scheduler.bm.num_free == 64, \
            f"node {nid} leaked blocks after kill"
        assert eng.scheduler.bm.utilization == 0.0
        eng.scheduler.bm.check_invariants()


def test_bandwidth_util_derived_from_decoded_tokens(small_model):
    """run_decode used to pin last_bandwidth_util = 1.0 before checking
    whether the batch progressed; it is now the decoded-token fraction."""
    cfg, params = small_model
    engine = NodeEngine(0, cfg, params, num_blocks=64, paged_decode="kernel")
    req = Request(prompt_tokens=list(range(1, 9)),
                  sampling=SamplingParams(max_new_tokens=3))
    engine.scheduler.enqueue_prefill(req)
    done, _ = engine.step()
    assert done and engine.scheduler.last_bandwidth_util == 0.0  # no decode yet
    engine.scheduler.enqueue_decode(req)
    engine.step()
    assert engine.scheduler.last_bandwidth_util == 1.0           # full progress
    # a stalled batch must read as zero pressure, not be masked to 1.0
    from repro.core.scheduler.hybrid_scheduler import ScheduleDecision
    saved = engine._decode_paged
    engine._decode_paged = lambda batch: 0                       # no progress
    try:
        engine.run_decode(ScheduleDecision(kind="decode", decode_batch=[req]))
    finally:
        engine._decode_paged = saved
    assert engine.scheduler.last_bandwidth_util == 0.0
    while req.num_output < 3:
        engine.step()
    engine.step()                                                # idle cycle
    assert engine.scheduler.last_bandwidth_util == 0.0


def test_paged_decode_mode_validation(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError):
        NodeEngine(0, cfg, params, paged_decode="bogus")
    # windowed attention has no kernel path: "kernel" must refuse, "auto"
    # must fall back to the dense oracle
    import dataclasses
    wcfg = dataclasses.replace(cfg, attn_window=4)
    with pytest.raises(ValueError):
        NodeEngine(0, wcfg, params, paged_decode="kernel")
    eng = NodeEngine(0, wcfg, params, paged_decode="auto")
    assert not eng.use_paged_decode
