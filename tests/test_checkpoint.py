"""Training checkpoint: atomic save, resume-from-latest, exact roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.api import get_model
from repro.serving.checkpoint import (latest_checkpoint, load_train_state,
                                      save_train_state)
from repro.training import optimizer as OPT


def test_train_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("gemma-2b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = OPT.init_state(params)
    state["step"] = jnp.asarray(7, jnp.int32)
    path = save_train_state(state, 7, str(tmp_path))
    assert os.path.exists(path)
    assert latest_checkpoint(str(tmp_path)) == path

    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = load_train_state(template, path)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6, atol=1e-6)
    assert int(restored["step"]) == 7


def test_latest_checkpoint_ordering(tmp_path):
    cfg = get_smoke_config("gemma-2b")
    model = get_model(cfg)
    state = OPT.init_state(model.init(jax.random.PRNGKey(0)))
    for step in (3, 10, 7):
        save_train_state(state, step, str(tmp_path))
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000010.npz")
    assert latest_checkpoint(str(tmp_path / "nope")) is None


def test_bf16_roundtrip(tmp_path):
    state = {"w": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
             "step": jnp.asarray(1, jnp.int32)}
    path = save_train_state(state, 1, str(tmp_path))
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = load_train_state(template, path)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(state["w"], np.float32))
