"""Fault-tolerant serving plane: deterministic fault injection, transfer
retry/backoff with integrity checks, token-exact crash recovery
(``src/repro/faults/``, the fault paths in ``serving/cluster.py`` and
``sim/cluster_sim.py``)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.faults import FaultInjector, FaultSpec, as_injector
from repro.models import transformer as T
from repro.models.api import get_model
from repro.obs.replay import capture, per_request_stats, replay
from repro.obs.tracing import attach_tracer, read_trace
from repro.serving.api import FlowKVClient
from repro.serving.cluster import PDCluster
from repro.serving.request import RequestState, SamplingParams
from repro.sim.cluster_sim import ClusterSim
from repro.sim.scenarios import get_scenario
from repro.sim.workload import SIMULATED, generate


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n=3, seed=5):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, cfg.vocab_size, size=rng.randint(5, 30)))
            for _ in range(n)]


@pytest.fixture(scope="module")
def chaos_case(small_model):
    """ONE prompt set + greedy reference shared by every chaos test.

    Each distinct prompt length compiles a fresh XLA prefill variant;
    sharing the workload keeps this module's compile count (and the whole
    suite's XLA footprint) bounded."""
    cfg, _, params = small_model
    prompts = _prompts(cfg)
    refs = {tuple(p): [int(x) for x in T.greedy_generate(
        params, cfg, jnp.asarray([p], jnp.int32), 8)[0]] for p in prompts}
    return prompts, refs


def _run_chaos(cfg, params, prompts, faults, *, num_prefill=1, num_decode=2,
               steps=8, max_cycles=400, **kw):
    """Submit ``prompts``, drive every stream round-robin to completion, and
    return (cluster, handles, streams)."""
    cluster = PDCluster(cfg, params, num_prefill=num_prefill,
                        num_decode=num_decode, num_blocks=128,
                        faults=faults, heartbeat_timeout_cycles=2.0, **kw)
    client = FlowKVClient.from_cluster(cluster)
    handles = [client.submit(list(p), SamplingParams(max_new_tokens=steps))
               for p in prompts]
    streams = {h.request_id: [] for h in handles}
    gens = {h.request_id: h.tokens(max_cycles=max_cycles) for h in handles}
    done = set()
    while len(done) < len(handles):
        for h in handles:
            if h.request_id in done:
                continue
            try:
                streams[h.request_id].append(next(gens[h.request_id]))
            except StopIteration:
                done.add(h.request_id)
    return cluster, handles, streams


def _assert_token_exact(handles, refs, streams=None):
    for h in handles:
        req = h.request
        key = tuple(req.prompt_tokens[:req.client_prompt_len]
                    if req.client_prompt_len else req.prompt_tokens)
        assert req.state is RequestState.FINISHED
        assert req.output_tokens == refs[key], (
            f"request {req.request_id} diverged after recovery")
        if streams is not None:
            assert streams[h.request_id] == req.output_tokens, (
                f"request {req.request_id} stream violated exactly-once")


# -- fault injector ----------------------------------------------------------------
def test_injector_is_deterministic_and_replayable():
    inj = FaultInjector([FaultSpec("node_crash", at=3.0, node_id=1),
                         FaultSpec("transfer_fail", at=0.0, rate=0.3),
                         FaultSpec("degraded_bandwidth", at=5.0,
                                   duration=2.0, factor=4.0)], seed=7)
    run1 = [inj.transfer_attempt(float(t)) for t in range(20)]
    inj.reset()
    run2 = [inj.transfer_attempt(float(t)) for t in range(20)]
    assert run1 == run2                       # seeded rate stream replays
    assert any(f == "fail" for f in run1)
    inj.reset()
    assert [s.node_id for s in inj.due(3.5)] == [1]
    assert inj.due(3.5) == []                 # one-shot: fires once
    assert inj.bandwidth_factor(6.0) == 4.0
    assert inj.bandwidth_factor(8.0) == 1.0   # window closed
    # meta round-trip rebuilds an equivalent injector (replay path)
    clone = as_injector(inj.to_meta())
    clone.reset()
    assert [clone.transfer_attempt(float(t)) for t in range(20)] == run1


# -- real cluster: crash recovery ---------------------------------------------------
def test_mid_prefill_kill_token_identity(small_model, chaos_case):
    """A prefill node dying mid-prefill reroutes its requests and the final
    tokens match the monolithic reference (nothing was half-prefixed)."""
    cfg, _, params = small_model
    prompts, refs = chaos_case
    cluster, handles, streams = _run_chaos(
        cfg, params, prompts,
        [FaultSpec("node_crash", at=1.0, node_id=0)],
        num_prefill=2, num_decode=1)
    _assert_token_exact(handles, refs, streams)
    assert cluster.stats()["fault_kills"] == 1
    cluster.assert_no_leaks()


def test_mid_decode_kill_exactly_once(small_model, chaos_case):
    """Killing a decode node mid-generation: recovery teacher-forces the
    emitted prefix, the stream sees every token exactly once (no replays,
    no gaps), and tokens are bit-identical to fault-free."""
    cfg, _, params = small_model
    prompts, refs = chaos_case
    cluster, handles, streams = _run_chaos(
        cfg, params, prompts,
        [FaultSpec("node_crash", at=4.0, node_id=1)])
    _assert_token_exact(handles, refs, streams)
    s = cluster.stats()
    assert s["recoveries"] >= 1, "the crash landed after all decodes"
    recovered = [h for h in handles if h.stats()["recovered"]]
    assert recovered
    for h in recovered:
        assert h.stats()["replayed_tokens"] >= 1
        assert h.stats()["recovery_s"] > 0.0
    cluster.assert_no_leaks()


def test_corruption_caught_retried_and_repaired(small_model, chaos_case):
    """A corrupted payload is caught by the per-plan checksum (never by
    luck), retried once, and the clean re-import repairs the pages."""
    cfg, _, params = small_model
    all_prompts, refs = chaos_case
    prompts = all_prompts[:2]
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128,
                        faults=[FaultSpec("transfer_corrupt", at=0.0,
                                          count=1)])
    rec = attach_tracer(cluster)
    client = FlowKVClient.from_cluster(cluster)
    handles = [client.submit(list(p), SamplingParams(max_new_tokens=8))
               for p in prompts]
    for h in handles:
        h.result(max_cycles=300)
    _assert_token_exact(handles, refs)
    assert cluster.stats()["transfer_retries"] == 1
    assert sum(h.stats()["transfer_retries"] for h in handles) == 1
    retry_spans = rec.by_name("transfer_retry")
    assert len(retry_spans) == 1
    assert retry_spans[0].attrs["fault"] == "corrupt"
    assert retry_spans[0].attrs["backoff_s"] > 0.0
    assert not rec.by_name("failure")         # retry succeeded: no failover
    cluster.assert_no_leaks()


def test_retry_exhaustion_degrades_to_recompute(small_model, chaos_case):
    """When every retry of a transfer fails, the request falls back to a
    full prefill recompute on the decode node — and still lands the exact
    reference tokens."""
    cfg, _, params = small_model
    all_prompts, refs = chaos_case
    prompts = all_prompts[:1]
    cluster, handles, _ = _run_chaos(
        cfg, params, prompts,
        # one fault per attempt: exhausts max_retries+1 attempts
        [FaultSpec("transfer_fail", at=0.0, count=4)],
        num_prefill=1, num_decode=1)
    _assert_token_exact(handles, refs)
    s = cluster.stats()
    assert s["degraded_to_recompute"] == 1
    assert s["transfer_retries"] == 4
    assert handles[0].stats()["recovered"]
    cluster.assert_no_leaks()


def test_kill_dst_mid_windowed_transfer_no_leak(small_model, chaos_case):
    """The decode node dying BETWEEN layer-window sub-plans must not leak
    the partially-imported dst blocks; the transfer restarts cleanly on a
    replacement node."""
    cfg, _, params = small_model
    all_prompts, refs = chaos_case
    prompts = all_prompts[:1]
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=2,
                        num_blocks=128, layer_window=1,
                        heartbeat_timeout_cycles=2.0)
    client = FlowKVClient.from_cluster(cluster)

    state = {"killed": None}
    for nid, eng in cluster.engines.items():
        if eng.node_id == 0:
            continue
        orig = eng.kv.import_plan

        def wrapper(engine_t, plan, src_pool, _orig=orig, _nid=nid):
            out = _orig(engine_t, plan, src_pool)
            if state["killed"] is None:       # die after the FIRST window
                state["killed"] = _nid
                cluster.kill_node(_nid)
            return out

        eng.kv.import_plan = wrapper

    h = client.submit(prompts[0], SamplingParams(max_new_tokens=8))
    h.result(max_cycles=400)
    assert state["killed"] is not None, "layer-window path never ran"
    _assert_token_exact([h], refs)
    assert h.request.decode_node != state["killed"]
    aborted = [t for t in cluster.transfers
               if t.status == "aborted_dst_dead"]
    assert aborted, "dead-dst abort path never triggered"
    assert cluster.stats()["leaked_blocks"] == 0
    cluster.assert_no_leaks()


def test_cancel_while_failed_in_retry_queue(small_model, chaos_case):
    """Cancelling a request parked controller-side (FAILED, unroutable)
    must beat the reroute: terminal CANCELLED, empty retry queue, zero
    blocks held anywhere."""
    cfg, _, params = small_model
    prompts, _ = chaos_case
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, heartbeat_timeout_cycles=2.0)
    client = FlowKVClient.from_cluster(cluster)
    h = client.submit(prompts[0], SamplingParams(max_new_tokens=32))
    for _ in range(4):                        # reach decode
        cluster.step()
    cluster.kill_node(0)
    cluster.kill_node(1)                      # whole fleet gone: unroutable
    for _ in range(6):                        # past the staleness window
        cluster.step()
    assert h.request.state is RequestState.FAILED
    assert h.request in cluster.controller.retry_queue
    assert h.cancel()
    assert h.request.state is RequestState.CANCELLED
    assert h.request not in cluster.controller.retry_queue
    assert cluster.audit_blocks() == 0
    assert list(h.tokens()) == h.request.output_tokens  # stream terminates
    cluster.assert_no_leaks()


@pytest.mark.parametrize("allocator", ["flowkv", "freelist"])
def test_no_leaks_after_chaos_both_allocators(small_model, chaos_case,
                                              allocator):
    """Full chaos (kill + corruption) leaves every surviving allocator with
    its invariants intact and zero orphaned tables."""
    cfg, _, params = small_model
    prompts, refs = chaos_case
    cluster, handles, _ = _run_chaos(
        cfg, params, prompts,
        [FaultSpec("node_crash", at=4.0, node_id=1),
         FaultSpec("transfer_corrupt", at=0.0, count=1)],
        allocator=allocator)
    _assert_token_exact(handles, refs)
    assert cluster.audit_blocks() == 0
    cluster.assert_no_leaks()                 # invariants + liveness sweep
    for nid, eng in cluster.engines.items():
        if nid in cluster._dead:
            continue
        bm = eng.scheduler.bm
        assert bm.free_capacity == bm.num_blocks   # returned or cached


def test_heartbeat_staleness_knob(small_model):
    """Liveness is pure staleness against ``heartbeat_timeout_cycles`` —
    no sentinel stamp. A quiet node stays alive inside the window and is
    declared dead only once it falls over the threshold."""
    cfg, _, params = small_model
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=64, heartbeat_timeout_cycles=3.0)
    cluster.step()
    cluster.step()
    cluster.kill_node(1)
    node = cluster.controller.nodes[1]
    assert node.last_heartbeat == 2.0         # real stamp, not a sentinel
    cluster.step()                            # staleness 1.0 < 3.0
    assert node.alive
    cluster.step()                            # 2.0
    cluster.step()                            # 3.0 — not strictly over yet
    assert node.alive
    cluster.step()                            # 4.0 > 3.0: dead
    assert not node.alive


# -- sim: scenario gate + replay ----------------------------------------------------
def test_sim_failure_scenario_meets_chaos_gate():
    sc = get_scenario("failure")
    chaos = sc.run("load_aware")
    clean = dataclasses.replace(sc, faults=()).run("load_aware")
    assert chaos["fault_kills"] == 1
    assert chaos["transfer_retries"] >= 1
    assert chaos["offered"] == chaos["finished"] + chaos["rejected"]
    assert chaos["leaked_blocks"] == 0
    assert chaos["goodput"] >= 0.7 * clean["goodput"]


def test_sim_chaos_run_is_deterministic():
    sc = get_scenario("failure")
    s1 = sc.run("load_aware")
    s2 = sc.run("load_aware")                 # fresh injector per build
    assert s1 == s2


def test_capture_replay_roundtrips_faults(tmp_path):
    """A chaos capture replays bit-identically: the faults meta rebuilds
    the same seeded injector, so crashes and retries re-fire in place."""
    cfg = get_config("llama31-8b")
    wl = dataclasses.replace(SIMULATED["1k"], num_requests=10)
    reqs = generate(wl, rps=2.0, seed=3)
    sim = ClusterSim(cfg, "flowkv", num_prefill=2, num_decode=2,
                     faults=[FaultSpec("node_crash", at=2.0, node_id=0),
                             FaultSpec("transfer_fail", at=0.0, count=2)],
                     heartbeat_timeout=1.0)
    path = tmp_path / "chaos.jsonl"
    stats, _ = capture(sim, reqs, path=path, meta={"config": "llama31-8b"})
    assert stats["fault_kills"] == 1
    trace = read_trace(path)
    assert trace.meta["faults"]["specs"]      # chaos is part of the capture
    assert trace.meta["heartbeat_timeout"] == 1.0
    r1 = replay(path)
    r2 = replay(path)
    assert r1["per_request"] == r2["per_request"]
    assert r1["stats"] == r2["stats"]
    assert r1["stats"]["fault_kills"] == stats["fault_kills"]
    assert r1["stats"]["transfer_retries"] == stats["transfer_retries"]
    # and the replay reproduces the ORIGINAL chaos run, not merely itself
    assert r1["per_request"] == per_request_stats(reqs)
