"""Model-family equivalence tests: prefill/decode must match teacher-forced
training forward for every family (the property FlowKV's P->D split relies
on)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import encdec as E
from repro.models import griffin as G
from repro.models import mamba2 as M2
from repro.models import transformer as T
from repro.models.common import ModelConfig


def _dense_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=32,
                vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=8,
                d_ff=64, dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("cfg", [
    _dense_cfg(),
    _dense_cfg(qk_norm=True),
    _dense_cfg(num_kv_heads=1, head_dim=16, num_heads=2, embed_scale=True,
               activation="gelu"),
    _dense_cfg(family="moe", d_ff=0, moe_d_ff=32, num_experts=4, top_k=2),
    _dense_cfg(family="moe", d_ff=0, moe_d_ff=32, num_experts=4, top_k=1),
], ids=["dense", "qk_norm", "mqa_gelu", "moe_top2", "moe_top1"])
def test_transformer_decode_matches_train(cfg):
    key = jax.random.PRNGKey(0)
    p = T.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 9), 0, cfg.vocab_size)
    logits, _ = T.forward_train(p, cfg, toks)
    assert logits.shape == (2, 9, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    lg, pre = T.prefill(p, cfg, toks)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-3)
    cache = T.init_cache(cfg, 2, 12, dtype=jnp.float32)
    cache["k"] = cache["k"].at[:, :, :9].set(pre["k"])
    cache["v"] = cache["v"].at[:, :, :9].set(pre["v"])
    cache["length"] = jnp.full((2,), 9, jnp.int32)
    full = jnp.concatenate([toks, toks[:, :3]], axis=1)
    logits_full, _ = T.forward_train(p, cfg, full)
    for i in range(3):
        lg, cache = T.decode_step(p, cfg, full[:, 9 + i], cache)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, 9 + i]),
                                   rtol=5e-3, atol=5e-3)


def test_mamba2_decode_matches_train():
    cfg = ModelConfig(name="m", family="ssm", num_layers=2, d_model=32,
                      vocab_size=64, ssm_state=8, ssm_expand=2, ssm_head_dim=8,
                      ssm_conv=4, ssm_chunk=4, dtype=jnp.float32)
    p = M2.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 11), 0, 64)
    logits, _ = M2.forward_train(p, cfg, toks)
    c = M2.init_cache(cfg, 2)
    for i in range(11):
        lg, c = M2.decode_step(p, cfg, toks[:, i], c)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, i]),
                                   rtol=5e-3, atol=5e-3)
    # chunk-size invariance
    cfg2 = dataclasses.replace(cfg, ssm_chunk=11)
    logits2, _ = M2.forward_train(p, cfg2, toks)
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(logits),
                               rtol=1e-4, atol=1e-4)


def test_griffin_decode_matches_train():
    cfg = ModelConfig(name="g", family="hybrid", num_layers=8, d_model=16,
                      vocab_size=50, num_heads=2, num_kv_heads=1, head_dim=8,
                      d_ff=32, attn_window=4, layer_pattern=("rec", "rec", "attn"),
                      lru_width=16, dtype=jnp.float32)
    p = G.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 11), 0, 50)
    logits, _ = G.forward_train(p, cfg, toks)
    lg, cache = G.prefill(p, cfg, toks)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-3)
    cc = dict(cache)
    full = jnp.concatenate([toks, toks[:, :3]], axis=1)
    logits_full, _ = G.forward_train(p, cfg, full)
    for i in range(3):
        lg, cc = G.decode_step(p, cfg, full[:, 11 + i], cc)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, 11 + i]),
                                   rtol=5e-3, atol=5e-3)


def test_encdec_decode_matches_train():
    cfg = ModelConfig(name="e", family="encdec", num_layers=2,
                      num_encoder_layers=2, d_model=16, vocab_size=50,
                      num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                      cross_attention=True, frontend="audio", dtype=jnp.float32)
    p = E.init_params(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 16))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, 50)
    logits, _ = E.forward_train(p, cfg, {"frames": frames, "tokens": toks})
    lg, cache = E.prefill(p, cfg, {"frames": frames, "tokens": toks})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-3)
    c = E.init_cache(cfg, 2, 10, 7, dtype=jnp.float32)
    c["k"] = c["k"].at[:, :, :6].set(cache["k"])
    c["v"] = c["v"].at[:, :, :6].set(cache["v"])
    c["cross_k"], c["cross_v"] = cache["cross_k"], cache["cross_v"]
    c["length"] = jnp.full((2,), 6, jnp.int32)
    full = jnp.concatenate([toks, toks[:, :2]], axis=1)
    logits_full, _ = E.forward_train(p, cfg, {"frames": frames, "tokens": full})
    for i in range(2):
        lg, c = E.decode_step(p, cfg, full[:, 6 + i], c)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, 6 + i]),
                                   rtol=5e-3, atol=5e-3)


def test_vlm_frontend_splice():
    cfg = _dense_cfg(family="vlm", frontend="vision", frontend_tokens=4)
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 64)
    fe = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 32))
    logits, _ = T.forward_train(p, cfg, toks, fe)
    assert logits.shape == (2, 10, 64)
    loss = T.loss_fn(p, cfg, {"tokens": toks, "labels": jnp.zeros((2, 10), jnp.int32),
                              "frontend_embeds": fe})
    assert bool(jnp.isfinite(loss))
