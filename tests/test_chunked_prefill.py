"""Chunked prefill + continuous batching: identity, fairness, parity.

The reproduction-critical property of Sarathi-style chunking on this stack:
splitting a prompt into suffix chunks (``q_offset = tokens_done``, pages
written back at ``start = tokens_done``) must be BIT-IDENTICAL to the
monolithic forward over the same positions — decoding is deterministic
argmax, so any drift in attention masking, page layout, or chunk
bookkeeping shows up as a wrong token, not a tolerance violation.

Covers the four satellite contracts:

* chunked == monolithic token identity at >=2 chunk sizes, cold AND on a
  prefix-cache hit (the chunk loop resumes AFTER the cached prefix);
* continuous batching — a request joins the decode batch while others are
  mid-decode and leaves without disturbing them, token-identically;
* the admission head-of-line fix — ``predicted_chunked_ttft_s`` prices a
  newcomer behind a giant backlog by the chunks that actually delay it,
  not the whole resident prompt;
* sim/real parity — ClusterSim grants byte-for-byte the same chunk
  sequence as the real engine for the same (prompt_len, chunk, block) and
  prices suffix chunks, so simulated A/Bs transfer to the real cluster.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.costmodel import predicted_chunked_ttft_s, predicted_ttft_s
from repro.models import transformer as T
from repro.models.api import get_model
from repro.obs.tracing import attach_tracer
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, SamplingParams
from repro.sim.cluster_sim import ClusterSim


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lengths, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=n).tolist() for n in lengths]


def _reference(cfg, params, prompts, steps):
    return {tuple(p): [int(x) for x in
                       T.greedy_generate(params, cfg,
                                         jnp.asarray([p], jnp.int32), steps)[0]]
            for p in prompts}


def _chunk_spans(recorder, request_id):
    return [s for s in recorder.spans
            if s.name == "prefill_chunk" and s.trace_id == request_id]


# ---------------------------------------------------------------------------
# bit-identity: chunked == monolithic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_tokens", [32, 48])
def test_chunked_matches_monolithic(small_model, chunk_tokens):
    """Cold chunked prefill is token-identical to the one-shot forward.

    Prompts straddle chunk boundaries on purpose: multi-chunk, exactly-one-
    chunk, and (for chunk=48, block=32) a chunk cap the block aligner must
    round down mid-prompt.
    """
    cfg, params = small_model
    prompts = _prompts(cfg, [100, 61, 33, 20], seed=1)
    refs = _reference(cfg, params, prompts, steps=5)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, prefix_reuse=False,
                        chunked_prefill=True,
                        prefill_chunk_tokens=chunk_tokens)
    rec = attach_tracer(cluster)
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=5))
            for p in prompts]
    done = cluster.run(reqs, max_cycles=120)
    assert len(done) == len(prompts)
    for r in done:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)], (
            f"chunk={chunk_tokens} prompt_len={r.prompt_len}: chunked "
            f"prefill diverged from monolithic")
    # the 100-token prompt really ran in pieces (the test must not pass
    # because chunking silently degraded to monolithic)
    long_req = next(r for r in done if r.prompt_len == 100)
    spans = _chunk_spans(rec, long_req.request_id)
    assert len(spans) >= 2, "long prompt did not actually chunk"
    assert sum(s.attrs["tokens"] for s in spans) == 100
    # chunks tile the prompt contiguously
    offs = sorted((s.attrs["offset"], s.attrs["tokens"]) for s in spans)
    pos = 0
    for off, tok in offs:
        assert off == pos
        pos += tok
    # non-final chunk boundaries land on block edges (write_prefill contract)
    for off, tok in offs[:-1]:
        assert (off + tok) % cfg.block_size == 0


def test_chunked_matches_monolithic_on_prefix_hit(small_model):
    """The chunk loop resumes AFTER a cached prefix, token-identically.

    Donor plants a 64-token prefix; followers share it and carry a suffix
    long enough to need several chunks starting at offset 64 (block-aligned
    by construction: 64 = 2 blocks).
    """
    cfg, params = small_model
    rng = np.random.RandomState(11)
    prefix = rng.randint(0, cfg.vocab_size, size=64).tolist()
    donor = prefix + rng.randint(0, cfg.vocab_size, size=10).tolist()
    followers = [prefix + rng.randint(0, cfg.vocab_size, size=70 + 7 * i).tolist()
                 for i in range(2)]
    refs = _reference(cfg, params, [donor] + followers, steps=4)

    # single hybrid node (P==D): the donor's blocks stay resident after its
    # local handoff, so followers hit without a cross-node fetch — the
    # fetch path has its own identity tests in test_prefix_reuse.py
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                        num_blocks=128, max_batch_tokens=4096,
                        chunked_prefill=True, prefill_chunk_tokens=32)
    rec = attach_tracer(cluster)
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=4))
            for p in [donor] + followers]
    cluster.submit(reqs[0])
    for _ in range(4):            # donor KV becomes resident + indexed
        cluster.step()
    for r in reqs[1:]:
        cluster.submit(r)
    for _ in range(150):
        cluster.step()
        if len(cluster.finished) == len(reqs):
            break
    assert len(cluster.finished) == len(reqs)
    for r in cluster.finished:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)], (
            "chunked prefill on a prefix hit diverged from monolithic")
    # the hit actually happened AND the followers still chunked their suffix
    assert sum(e.prefix_hits for e in cluster.engines.values()) >= 1
    hit = next(r for r in reqs[1:] if r.num_cached_prefix_tokens > 0)
    spans = _chunk_spans(rec, hit.request_id)
    assert len(spans) >= 2
    assert min(s.attrs["offset"] for s in spans) == hit.num_cached_prefix_tokens
    assert sum(s.attrs["tokens"] for s in spans) == (
        hit.prompt_len - hit.num_cached_prefix_tokens), (
        "chunk budget double-counted the cached prefix")


# ---------------------------------------------------------------------------
# continuous batching: join/leave mid-flight
# ---------------------------------------------------------------------------

def test_continuous_join_and_leave_identity(small_model):
    """A late request joins while others decode; an early one leaves.

    Neither event may perturb anyone's tokens — the decode batch re-forms
    between cycles, not at lockstep cycle-group boundaries.
    """
    cfg, params = small_model
    prompts = _prompts(cfg, [90, 70, 25], seed=3)
    steps = {0: 10, 1: 3, 2: 6}   # req 1 leaves early, req 2 joins late
    refs = {tuple(p): [int(x) for x in
                       T.greedy_generate(params, cfg,
                                         jnp.asarray([p], jnp.int32), n)[0]]
            for p, n in zip(prompts, steps.values())}
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, prefix_reuse=False,
                        chunked_prefill=True, prefill_chunk_tokens=32)
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=steps[i]))
            for i, p in enumerate(prompts)]
    cluster.submit(reqs[0])
    cluster.submit(reqs[1])
    joined_mid_decode = False
    late_submitted = False
    for _ in range(200):
        cluster.step()
        decoding = {r.request_id
                    for e in cluster.engines.values()
                    for r in e.scheduler.decode.running}
        if not late_submitted and reqs[0].request_id in decoding:
            cluster.submit(reqs[2])   # joins while 0 (and maybe 1) decode
            late_submitted = True
        if late_submitted and reqs[2].request_id in decoding and \
                reqs[0].request_id in decoding:
            joined_mid_decode = True
        if len(cluster.finished) == len(reqs):
            break
    assert len(cluster.finished) == len(reqs)
    assert joined_mid_decode, (
        "late request never shared a decode batch with an in-flight one — "
        "batching is lockstep, not continuous")
    for r in cluster.finished:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)]
    # the short-budget request left first, while req 0 kept decoding
    finish_order = [r.request_id for r in cluster.finished]
    assert finish_order.index(reqs[1].request_id) < \
        finish_order.index(reqs[0].request_id)


# ---------------------------------------------------------------------------
# admission head-of-line regression (costmodel fix)
# ---------------------------------------------------------------------------

def test_admission_prices_chunks_not_whole_prompts():
    """A newcomer behind a 100k-token resident prompt is delayed by the
    chunks interleaved ahead of it, not by the whole resident prompt.

    This is the head-of-line bias the old whole-backlog estimate had: it
    priced the 128-token newcomer as if it had to wait out all 100k tokens,
    so the router steered everything away from any node serving a long
    prompt even though chunking bounds the actual delay.
    """
    fpt, eff = 1e9, 1e14
    legacy = predicted_ttft_s(100_000 * fpt, 128 * fpt, eff)
    chunked = predicted_chunked_ttft_s([100_000], 128, 512, fpt, eff)
    # newcomer needs 1 chunk cycle -> at most 512 backlog tokens cut ahead
    assert chunked < 0.05 * legacy
    expect = predicted_ttft_s(512 * fpt, 128 * fpt, eff)
    assert chunked == pytest.approx(expect)

    # small backlogs are NOT under-priced: everything fits in one cycle, so
    # the chunked estimate degenerates to the legacy whole-backlog one
    small = [100, 60]
    assert predicted_chunked_ttft_s(small, 128, 512, fpt, eff) == \
        pytest.approx(predicted_ttft_s(sum(small) * fpt, 128 * fpt, eff))

    # monotone in backlog, bounded by own_cycles * chunk per competitor
    lo = predicted_chunked_ttft_s([1_000], 1024, 512, fpt, eff)
    hi = predicted_chunked_ttft_s([2_000], 1024, 512, fpt, eff)
    cap = predicted_chunked_ttft_s([10 ** 9], 1024, 512, fpt, eff)
    assert lo <= hi <= cap
    assert cap == pytest.approx(
        predicted_ttft_s(2 * 512 * fpt, 1024 * fpt, eff))


# ---------------------------------------------------------------------------
# sim/real parity
# ---------------------------------------------------------------------------

def test_sim_matches_engine_chunk_sequence(small_model):
    """ClusterSim grants the same per-request chunk sequence as the engine.

    Same config (block_size, layers), same prompt lengths, same chunk cap:
    the sim's scheduler IS the engine's scheduler, but the wiring (budget
    overrides, chunk knobs, lockstep fallbacks) could diverge — this pins
    the granted (offset, tokens) sequences byte-for-byte via the shared
    ``prefill_chunk`` span stream.
    """
    cfg, params = small_model
    lengths = [100, 61, 33]
    chunk = 48

    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, prefix_reuse=False,
                        chunked_prefill=True, prefill_chunk_tokens=chunk)
    rec_real = attach_tracer(cluster)
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=2))
            for p in _prompts(cfg, lengths, seed=5)]
    cluster.run(reqs, max_cycles=120)
    real = {r.prompt_len: sorted(
        (s.attrs["offset"], s.attrs["tokens"])
        for s in _chunk_spans(rec_real, r.request_id)) for r in reqs}

    sim = ClusterSim(cfg, "flowkv", num_prefill=1, num_decode=1,
                     chunked_prefill=True, prefill_chunk_tokens=chunk)
    rec_sim = attach_tracer(sim)
    sreqs = [Request(prompt_tokens=[0] * n,
                     sampling=SamplingParams(max_new_tokens=2),
                     arrival_time=0.0) for n in lengths]
    sim.run(sreqs, t_max=10_000.0)
    simulated = {r.prompt_len: sorted(
        (s.attrs["offset"], s.attrs["tokens"])
        for s in _chunk_spans(rec_sim, r.request_id)) for r in sreqs}

    assert real == simulated, (
        f"sim grants different chunks than the engine:\n real={real}\n  "
        f"sim={simulated}")
    assert any(len(v) >= 2 for v in real.values())   # actually chunked
