"""Observability plane: span tracing, trace capture/replay, calibration,
bench history (``src/repro/obs/``)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import TransportProfile, predicted_ttft_s
from repro.obs import attach_tracer, read_trace, write_trace
from repro.obs.calibrate import fit_compute, fit_hardware, fit_transport
from repro.obs.history import AREAS, check, check_metrics, load, record
from repro.obs.replay import capture, per_request_stats, replay
from repro.obs.tracing import (SPAN_NAMES, TRACE_SCHEMA_VERSION, Span,
                               SpanRecorder, request_record)
from repro.sim.cluster_sim import ClusterSim
from repro.sim.hardware import A100
from repro.sim.workload import SIMULATED, generate


@pytest.fixture(scope="module")
def cfg8b():
    return get_config("llama31-8b")


def _requests(n=10, seed=3):
    wl = dataclasses.replace(SIMULATED["1k"], num_requests=n)
    return generate(wl, rps=2.0, seed=seed)


# -- span schema / JSONL round-trip ------------------------------------------------
def test_span_record_roundtrip_drops_none():
    s = Span(trace_id=7, name="prefill", start_cycle=1.0, end_cycle=3.5,
             node_id=0, attrs={"prompt_len": 64})
    rec = s.to_record()
    assert "start_wall_s" not in rec          # None fields omitted
    assert Span.from_record(rec) == s
    assert s.duration_cycles() == 2.5
    assert s.duration_wall_s() is None


def test_trace_jsonl_roundtrip(tmp_path):
    rec = SpanRecorder()
    for i in range(3):
        rec.emit(i, SPAN_NAMES[i], start_cycle=float(i), end_cycle=i + 1.0,
                 start_wall_s=0.5 * i, end_wall_s=0.5 * i + 0.1,
                 node_id=i % 2, attrs={"k": i})
    reqs = [request_record(i, 0.25 * i, 100 + i, 64) for i in range(3)]
    path = write_trace(tmp_path / "t.jsonl", rec.spans, reqs,
                       meta={"system": "flowkv"})
    trace = read_trace(path)
    assert trace.schema == TRACE_SCHEMA_VERSION
    assert trace.meta["system"] == "flowkv"
    assert trace.requests == reqs
    assert trace.spans == rec.spans
    # header must be the first record and carry a supported schema
    lines = path.read_text().splitlines()
    assert json.loads(lines[0])["kind"] == "header"


def test_read_trace_rejects_bad_schema_and_kind(tmp_path):
    p = tmp_path / "bad_schema.jsonl"
    p.write_text('{"kind": "header", "schema": 999}\n')
    with pytest.raises(ValueError, match="schema"):
        read_trace(p)
    p2 = tmp_path / "bad_kind.jsonl"
    p2.write_text('{"kind": "header", "schema": %d}\n{"kind": "mystery"}\n'
                  % TRACE_SCHEMA_VERSION)
    with pytest.raises(ValueError, match="mystery"):
        read_trace(p2)
    p3 = tmp_path / "headless.jsonl"
    p3.write_text('{"kind": "span", "trace_id": 1, "name": "queue"}\n')
    with pytest.raises(ValueError, match="header"):
        read_trace(p3)


def test_trace_schema_v1_still_reads(tmp_path):
    # v2 added span kinds only — pre-existing v1 captures must keep reading
    p = tmp_path / "v1.jsonl"
    p.write_text('{"kind": "header", "schema": 1}\n'
                 '{"kind": "span", "trace_id": 1, "name": "prefill", '
                 '"start_cycle": 0.0, "end_cycle": 1.0}\n')
    trace = read_trace(p)
    assert trace.schema == 1
    assert len(trace.spans) == 1


def test_chunk_and_layer_window_span_kinds_roundtrip(tmp_path):
    assert "prefill_chunk" in SPAN_NAMES
    assert "transfer_layer_window" in SPAN_NAMES
    rec = SpanRecorder()
    rec.emit(1, "prefill_chunk", start_cycle=0.0, end_cycle=1.0, node_id=0,
             attrs={"offset": 0, "tokens": 32, "prompt_len": 96,
                    "final": False})
    rec.emit(1, "transfer_layer_window", start_cycle=0.5, end_cycle=0.9,
             node_id=0, attrs={"layer_lo": 0, "layer_hi": 8, "hidden": True})
    path = write_trace(tmp_path / "t2.jsonl", rec.spans)
    assert read_trace(path).spans == rec.spans


# -- sim tracing ---------------------------------------------------------------------
def test_sim_emits_lifecycle_spans(cfg8b):
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=1, num_decode=1)
    rec = attach_tracer(sim)
    reqs = _requests()
    sim.run(reqs, t_max=50_000)
    by_name = {n: rec.by_name(n) for n in ("queue", "prefill", "transfer",
                                           "decode")}
    for name, spans in by_name.items():
        assert len(spans) == len(reqs), name
        for s in spans:
            # sim spans run on the simulated clock only
            assert s.start_wall_s is None and s.end_wall_s is None
            assert s.duration_cycles() is not None
            assert s.duration_cycles() >= 0.0
    # every request's spans are causally ordered: queue ends where prefill
    # starts; decode starts at transfer end
    for r in reqs:
        spans = {s.name: s for s in rec.for_trace(r.request_id)}
        assert spans["queue"].end_cycle == spans["prefill"].start_cycle
        assert spans["transfer"].end_cycle == spans["decode"].start_cycle


def test_sim_emits_layer_window_spans(cfg8b):
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=1, num_decode=1,
                     same_host=False, layer_window=8)
    rec = attach_tracer(sim)
    reqs = _requests(n=4, seed=9)
    sim.run(reqs, t_max=50_000)
    n_layers = sim.kv_spec.num_layers
    windows_per_xfer = -(-n_layers // 8)
    wspans = rec.by_name("transfer_layer_window")
    assert len(wspans) == windows_per_xfer * len(rec.by_name("transfer"))
    for r in reqs:
        ws = sorted((s for s in rec.for_trace(r.request_id)
                     if s.name == "transfer_layer_window"),
                    key=lambda s: s.attrs["layer_lo"])
        xfer = [s for s in rec.for_trace(r.request_id)
                if s.name == "transfer"][0]
        # windows tile the layer axis and the last window lands exactly at
        # the parent transfer span's end (the exposed remainder)
        assert ws[0].attrs["layer_lo"] == 0
        assert ws[-1].attrs["layer_hi"] == n_layers
        for a, b in zip(ws, ws[1:]):
            assert a.attrs["layer_hi"] == b.attrs["layer_lo"]
        assert ws[-1].end_cycle == pytest.approx(xfer.end_cycle)
        # overlap is real: at least one window fully hidden behind prefill
        assert any(s.attrs["hidden"] for s in ws)
        assert xfer.attrs["hidden_s"] > 0.0


# -- replay ---------------------------------------------------------------------------
def test_replay_is_deterministic(cfg8b, tmp_path):
    reqs = _requests()
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=1, num_decode=1)
    path = tmp_path / "cap.jsonl"
    capture(sim, reqs, path=path, meta={"config": "llama31-8b"})
    r1 = replay(path, policy="load_aware")
    r2 = replay(path, policy="load_aware")
    assert r1["per_request"] == r2["per_request"]
    assert r1["stats"] == r2["stats"]
    # and the replay reproduces the ORIGINAL run, not merely itself
    assert r1["per_request"] == per_request_stats(reqs)


def test_replay_policy_changes_schedule_not_workload(cfg8b, tmp_path):
    reqs = _requests(n=8, seed=5)
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=2, num_decode=2)
    path = tmp_path / "cap.jsonl"
    capture(sim, reqs, path=path, meta={"config": "llama31-8b"})
    la = replay(path, policy="load_aware")
    rr = replay(path, policy="round_robin")
    assert la["stats"]["offered"] == rr["stats"]["offered"] == 8
    assert la["policy"] == "load_aware" and rr["policy"] == "round_robin"
    # same request shapes either way
    for rid, row in la["per_request"].items():
        assert row["prompt_len"] == rr["per_request"][rid]["prompt_len"]


def test_replay_rejects_spans_only_trace(tmp_path):
    rec = SpanRecorder()
    rec.emit(1, "queue", start_cycle=0.0, end_cycle=1.0)
    path = write_trace(tmp_path / "spans_only.jsonl", rec.spans)
    with pytest.raises(ValueError, match="request"):
        replay(path)


# -- calibration ----------------------------------------------------------------------
def test_fit_transport_recovers_known_profile():
    truth = TransportProfile(name="truth", per_call_s=75e-6,
                             bandwidth_Bps=20e9, fixed_s=3e-4)
    rng = np.random.RandomState(0)
    samples = [(int(c), int(b), truth.latency(int(c), int(b)))
               for c, b in zip(rng.randint(1, 500, 12),
                               rng.randint(1 << 10, 1 << 28, 12))]
    fit = fit_transport(samples)
    assert fit.per_call_s == pytest.approx(truth.per_call_s, rel=1e-6)
    assert fit.bandwidth_Bps == pytest.approx(truth.bandwidth_Bps, rel=1e-6)
    assert fit.fixed_s == pytest.approx(truth.fixed_s, rel=1e-6)
    with pytest.raises(ValueError, match=">= 3"):
        fit_transport(samples[:2])


def test_fit_hardware_recovers_known_coefficients():
    eff_truth, ovh_truth = 150e9, 2.5e-3
    samples = [(f, ovh_truth + f / eff_truth)
               for f in (1e9, 5e9, 2e10, 1e11)]
    eff, ovh = fit_compute(samples)
    assert eff == pytest.approx(eff_truth, rel=1e-6)
    assert ovh == pytest.approx(ovh_truth, rel=1e-6)
    hw = fit_hardware(samples, base=A100, name="fit")
    # the fitted profile's prefill_time (== predicted_ttft_s) reproduces
    # the samples — calibration lands exactly in the controller's formula
    for f, t in samples:
        assert hw.prefill_time(f) == pytest.approx(t, rel=1e-6)
        assert predicted_ttft_s(0.0, f, hw.peak_flops * hw.mfu_prefill,
                                hw.step_overhead_s) == pytest.approx(t, rel=1e-6)


# -- wall-clock request stats (the satellite bugfix) -----------------------------------
def test_timing_breakdown_has_wall_fields():
    from repro.serving.request import Request
    r = Request(prompt_tokens=[1, 2, 3])
    bd = r.timing_breakdown()
    for key in ("queue_wall_s", "prefill_wall_s", "transfer_wall_s",
                "decode_wall_s", "ttft_wall_s", "e2e_wall_s"):
        assert key in bd and bd[key] is None   # nothing stamped yet
    r.arrival_wall, r.first_token_wall, r.finish_wall = 1.0, 3.5, 7.25
    bd = r.timing_breakdown()
    assert bd["ttft_wall_s"] == 2.5
    assert bd["e2e_wall_s"] == 6.25


# -- bench history ---------------------------------------------------------------------
def test_history_record_and_check(tmp_path):
    m = {"flowkv_calls": 1.0, "flowkv_dispatches": 1.0, "flowkv_wall_s": 0.1}
    record("transfer", m, root=tmp_path)
    data = load("transfer", root=tmp_path)
    assert data["baseline"] == m and len(data["entries"]) == 1
    # identical follow-up: passes
    record("transfer", dict(m), root=tmp_path)
    assert check("transfer", root=tmp_path) == []
    # structural drift: exact metric fails
    record("transfer", {**m, "flowkv_calls": 2.0}, root=tmp_path)
    failures = check("transfer", root=tmp_path)
    assert failures and "flowkv_calls" in failures[0]
    # wall-clock drift alone: informational, never fails
    record("transfer", {**m, "flowkv_wall_s": 99.0}, root=tmp_path)
    assert check("transfer", root=tmp_path) == []


def test_history_modes_le_ge():
    base = {"imbalance_load_aware_goodput": 0.8,
            "imbalance_load_aware_p95_ttft_s": 10.0}
    ok = check_metrics("scenarios", base, {
        "imbalance_load_aware_goodput": 0.79,      # within 2% tolerance
        "imbalance_load_aware_p95_ttft_s": 10.4})  # within 5%
    assert ok == []
    bad = check_metrics("scenarios", base, {
        "imbalance_load_aware_goodput": 0.7,
        "imbalance_load_aware_p95_ttft_s": 12.0})
    assert len(bad) == 2
    # a metric the baseline never saw is not gated; a missing one is
    assert check_metrics("scenarios", base,
                         {"imbalance_load_aware_goodput": 0.8}) != []


def test_history_schema_guard(tmp_path):
    p = tmp_path / "BENCH_transfer.json"
    p.write_text('{"schema": 999, "area": "transfer"}')
    with pytest.raises(ValueError, match="schema"):
        load("transfer", root=tmp_path)
    with pytest.raises(ValueError, match="unknown area"):
        record("nonsense", {}, root=tmp_path)
    assert all(spec.mode in ("exact", "le", "ge", "info")
               for specs in AREAS.values() for spec in specs.values())
