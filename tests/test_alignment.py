"""Property tests: bidirectional segment alignment (paper Fig. 5).

``hypothesis`` is optional: without it the property tests skip (via
``pytest.importorskip``) and a deterministic seeded-random fallback still
exercises the roundtrip invariant.
"""
import random

import pytest

from repro.core.alignment import align, reconstruct

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @st.composite
    def _block_lists(draw):
        n = draw(st.integers(0, 120))
        src = draw(st.permutations(range(200)).map(lambda p: list(p[:n])))
        dst = draw(st.permutations(range(200)).map(lambda p: list(p[:n])))
        return src, dst

    @given(_block_lists())
    @settings(max_examples=80, deadline=None)
    def test_align_reconstruct_roundtrip(lists):
        src, dst = lists
        res = align(src, dst)
        rs, rd = reconstruct(res)
        assert rs == src and rd == dst
        assert res.num_blocks == len(src)
        # every run is contiguous on BOTH sides by construction
        for run in res.runs:
            assert run.src.length == run.dst.length

    @given(st.integers(1, 200), st.integers(0, 50), st.integers(0, 50))
    @settings(max_examples=40, deadline=None)
    def test_align_ideal_case_single_call(n, off_s, off_d):
        """Both sides contiguous -> exactly one call (paper's O(n) -> O(1))."""
        res = align(list(range(off_s, off_s + n)), list(range(off_d, off_d + n)))
        assert res.num_calls == 1
        assert res.merge_ratio == n
else:
    def test_hypothesis_property_suite():
        pytest.importorskip("hypothesis")   # records the skip reason


# -- deterministic fallback: same invariants, seeded random inputs -------------
def test_align_reconstruct_roundtrip_deterministic():
    rng = random.Random(0)
    for trial in range(50):
        n = rng.randint(0, 120)
        src = rng.sample(range(200), n)
        dst = rng.sample(range(200), n)
        res = align(src, dst)
        rs, rd = reconstruct(res)
        assert rs == src and rd == dst
        assert res.num_blocks == n
        for run in res.runs:
            assert run.src.length == run.dst.length


def test_align_ideal_case_single_call_deterministic():
    for n, off_s, off_d in ((1, 0, 0), (7, 3, 11), (200, 50, 0)):
        res = align(list(range(off_s, off_s + n)), list(range(off_d, off_d + n)))
        assert res.num_calls == 1
        assert res.merge_ratio == n


def test_align_partial_runs():
    res = align([0, 1, 2, 5, 6], [3, 4, 5, 6, 7])
    assert res.num_calls == 2
    assert [r.length for r in res.runs] == [3, 2]


def test_align_hostile_interleave():
    """One side reversed -> no merging possible."""
    src = list(range(10))
    dst = list(range(9, -1, -1))
    assert align(src, dst).num_calls == 10


def test_align_empty_and_mismatch():
    assert align([], []).num_calls == 0
    with pytest.raises(ValueError):
        align([1], [1, 2])
