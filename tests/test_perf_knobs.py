"""The §Perf optimization knobs must be semantics-preserving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig, dense_init
from repro.models import transformer as T
from repro.models.moe import moe_ffn, moe_ffn_gshard_einsum, moe_param_shapes


def _moe_cfg(**kw):
    base = dict(name="m", family="moe", num_layers=2, d_model=16, vocab_size=32,
                num_heads=2, num_kv_heads=2, head_dim=8,
                num_experts=4, top_k=2, moe_d_ff=32, dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


def test_gshard_einsum_matches_dense_dispatch():
    cfg = _moe_cfg()
    shapes = moe_param_shapes(cfg)
    p = {n: dense_init(k, s, jnp.float32)
         for (n, s), k in zip(shapes.items(),
                              jax.random.split(jax.random.PRNGKey(0), len(shapes)))}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    ref, _ = moe_ffn(p, x, cfg)
    out, _ = moe_ffn_gshard_einsum(p, x, cfg, capacity_factor=8.0)  # no drops
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_gshard_einsum_tight_capacity_finite_and_differentiable():
    cfg = _moe_cfg()
    shapes = moe_param_shapes(cfg)
    p = {n: dense_init(k, s, jnp.float32)
         for (n, s), k in zip(shapes.items(),
                              jax.random.split(jax.random.PRNGKey(0), len(shapes)))}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    out, aux = moe_ffn_gshard_einsum(p, x, cfg, capacity_factor=1.0)
    assert bool(jnp.all(jnp.isfinite(out))) and bool(jnp.isfinite(aux))
    g = jax.grad(lambda pp: moe_ffn_gshard_einsum(pp, x, cfg, 1.25)[0].sum())(p)
    assert bool(jnp.all(jnp.isfinite(g["w_down"])))


def test_remat_preserves_loss_and_grads():
    base = _moe_cfg(family="dense", d_ff=32, num_experts=0, top_k=0, moe_d_ff=0)
    p = T.init_params(base, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 32)
    batch = {"tokens": toks, "labels": toks}
    loss0, g0 = jax.value_and_grad(lambda pp: T.loss_fn(pp, base, batch))(p)
    for mode in ("full", "dots"):
        cfg = dataclasses.replace(base, remat=mode)
        loss1, g1 = jax.value_and_grad(lambda pp: T.loss_fn(pp, cfg, batch))(p)
        np.testing.assert_allclose(float(loss1), float(loss0), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


def test_head_padding_with_zero_weights_preserves_logits():
    """The llava hillclimb: zero-init padded heads change nothing."""
    base = _moe_cfg(family="dense", d_ff=32, num_experts=0, top_k=0, moe_d_ff=0,
                    num_heads=2, num_kv_heads=1)
    padded = dataclasses.replace(base, num_heads=4)
    p = T.init_params(base, jax.random.PRNGKey(0))
    pp = T.init_params(padded, jax.random.PRNGKey(0))
    # copy shared weights; zero the extra head columns
    lp, lpp = p["layers"], pp["layers"]
    lpp["wq"] = lpp["wq"].at[:].set(0).at[:, :, :2, :].set(lp["wq"])
    lpp["wo"] = lpp["wo"].at[:].set(0).at[:, :2, :, :].set(lp["wo"])
    for k in ("wk", "wv", "norm_attn", "norm_mlp", "w_gate", "w_up", "w_down"):
        lpp[k] = lp[k]
    pp["embed"] = p["embed"]
    pp["final_norm"] = p["final_norm"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 32)
    ref, _ = T.forward_train(p, base, toks)
    out, _ = T.forward_train(pp, padded, toks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_tp_reduce_bf16_close_to_f32():
    from repro.models import mamba2 as M2
    cfg = ModelConfig(name="s", family="ssm", num_layers=2, d_model=32,
                      vocab_size=64, ssm_state=8, ssm_expand=2, ssm_head_dim=8,
                      ssm_conv=4, ssm_chunk=4, dtype=jnp.bfloat16)
    p = M2.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    ref, _ = M2.forward_train(p, cfg, toks)
    cfg2 = dataclasses.replace(cfg, tp_reduce_bf16=True)
    out, _ = M2.forward_train(p, cfg2, toks)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=0.1, atol=0.15)
