"""Sharded disaggregated serving: mesh-parallel engines + per-shard-pair
fused KV transfer.

The reproduction-critical properties:

* a TP=2 NodeEngine (single-controller emulation: per-shard params, concat
  before every combine contraction) produces BIT-IDENTICAL greedy tokens to
  the single-device engine, dense and MoE;
* a cross-degree P->D transfer (TP=2 -> TP=1, 1 -> 2, 2 -> 4, ...) lands
  bit-identical pool contents to the unsharded reference transfer and costs
  exactly one fused dispatch per overlapping (src_shard, dst_shard) pair;
* the fault plane (checksums, retries, node kills, leak audit) works
  unchanged through sharded pools.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.costmodel import sharded_transfer_calls
from repro.core.layout import KVCacheSpec
from repro.core.transfer import (ShardedTransferEngine, ShardSpec,
                                 TransferEngine, shard_pairs,
                                 verify_sharded_transfer)
from repro.models import transformer as T
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, SamplingParams


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_smoke_config("qwen3-1.7b")
    from repro.models.api import get_model
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def moe_model():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    from repro.models.api import get_model
    params = get_model(cfg).init(jax.random.PRNGKey(1))
    return cfg, params


def _prompts(cfg, n=3, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, cfg.vocab_size, size=rng.randint(6, 24)))
            for _ in range(n)]


def _reference(cfg, params, prompts, steps):
    return {tuple(p): [int(x) for x in
                       T.greedy_generate(params, cfg,
                                         jnp.asarray([p], jnp.int32), steps)[0]]
            for p in prompts}


def _run_cluster(cfg, params, prompts, steps, **kw):
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, **kw)
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=steps))
            for p in prompts]
    done = cluster.run(reqs, max_cycles=120)
    assert len(done) == len(prompts)
    return cluster, done


# ---------------------------------------------------------------------------
# token identity: sharded engines vs the single-device reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model_fixture", ["dense_model", "moe_model"])
def test_tp2_cluster_token_identity(model_fixture, request):
    """TP=2 prefill AND decode: every output token bit-identical to the
    monolithic single-device generation (dense and MoE)."""
    cfg, params = request.getfixturevalue(model_fixture)
    prompts = _prompts(cfg)
    refs = _reference(cfg, params, prompts, steps=5)
    cluster, done = _run_cluster(cfg, params, prompts, 5,
                                 tp_degrees={0: 2, 1: 2})
    for r in done:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)]
    stats = cluster.stats()
    assert stats["sharded_nodes"] == 2
    assert stats["max_tp_degree"] == 2
    # same-degree tp=2 -> tp=2: 2 aligned pairs, one fused dispatch each
    assert stats["mean_transfer_dispatches"] == sharded_transfer_calls(2, 2)
    assert stats["shard_dispatches"] > 0
    assert stats["leaked_blocks"] == 0.0


def test_moe_tp2_reports_ep_degree(moe_model):
    cfg, params = moe_model
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=64, tp_degrees={0: 2})
    assert cluster.engines[0].tp_degree == 2
    assert cluster.engines[0].ep_degree == 2   # experts shard with the mesh
    assert cluster.engines[1].tp_degree == 1
    assert cluster.engines[1].ep_degree == 1


@pytest.mark.parametrize("tp_degrees,expected_dispatches", [
    ({0: 2, 1: 1}, 2),   # TP=2 prefill -> TP=1 decode
    ({0: 1, 1: 2}, 2),   # TP=1 prefill -> TP=2 decode
])
def test_cross_degree_cluster_token_identity(dense_model, tp_degrees,
                                             expected_dispatches):
    """Cross-degree disaggregation: resharding happens inside the transfer
    (per-pair fused dispatches), tokens stay bit-identical."""
    cfg, params = dense_model
    prompts = _prompts(cfg, seed=2)
    refs = _reference(cfg, params, prompts, steps=5)
    cluster, done = _run_cluster(cfg, params, prompts, 5,
                                 tp_degrees=tp_degrees)
    for r in done:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)]
    stats = cluster.stats()
    assert stats["mean_transfer_dispatches"] == expected_dispatches
    assert expected_dispatches == sharded_transfer_calls(
        tp_degrees.get(0, 1), tp_degrees.get(1, 1))
    assert stats["leaked_blocks"] == 0.0


def test_request_handle_surfaces_sharding(dense_model):
    from repro.serving.api import FlowKVClient
    cfg, params = dense_model
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=64, tp_degrees={0: 2, 1: 1})
    handle = client.submit(_prompts(cfg, n=1, seed=4)[0],
                           SamplingParams(max_new_tokens=3))
    handle.result()
    stats = handle.stats()
    assert stats["prefill_tp_degree"] == 2
    assert stats["decode_tp_degree"] == 1
    assert stats["prefill_ep_degree"] == 1
    assert stats["shard_dispatches"] == sharded_transfer_calls(2, 1)


# ---------------------------------------------------------------------------
# cross-degree transfer bit-exactness (pure transfer plane, synthetic pools)
# ---------------------------------------------------------------------------
_SPEC = KVCacheSpec(num_layers=2, num_blocks=12, block_size=4,
                    num_kv_heads=8, head_dim=4, dtype=jnp.float32)


def _full_pool(seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*_SPEC.shape).astype(np.float32))


def _shard_pool(pool, tp):
    """Slice a full-width FLOWKV pool into per-shard kv-head slices."""
    nb, L = _SPEC.num_blocks, _SPEC.num_layers
    bs, kv, hd = _SPEC.block_size, _SPEC.num_kv_heads, _SPEC.head_dim
    kw = kv // tp
    six = np.asarray(pool).reshape(nb, L, 2, bs, kv, hd)
    return [jnp.asarray(six[..., s * kw:(s + 1) * kw, :].reshape(nb, L, 2, -1))
            for s in range(tp)]


def _unshard_pool(pools, tp):
    nb, L = _SPEC.num_blocks, _SPEC.num_layers
    bs, kv, hd = _SPEC.block_size, _SPEC.num_kv_heads, _SPEC.head_dim
    six = [np.asarray(p).reshape(nb, L, 2, bs, kv // tp, hd) for p in pools]
    return np.concatenate(six, axis=4).reshape(_SPEC.shape)


@pytest.mark.parametrize("tp_src,tp_dst", [(2, 1), (1, 2), (2, 4), (4, 2),
                                           (2, 2)])
def test_cross_degree_transfer_bit_exact(tp_src, tp_dst):
    """A sharded transfer between pools of ANY degree pair lands the exact
    bytes the unsharded reference transfer lands, in exactly one fused
    dispatch per overlapping shard pair."""
    src_full = _full_pool(seed=10)
    dst_full = _full_pool(seed=11)
    src_blocks, dst_blocks = [1, 2, 3, 7], [0, 4, 5, 9]

    # unsharded oracle: classic whole-payload engine on the full pools
    ref_engine = TransferEngine(_SPEC)
    ref_plan = ref_engine.planner.plan("flowkv", src_blocks, dst_blocks)
    ref_dst = ref_engine.execute(ref_plan, src_full, dst_full)

    engine = ShardedTransferEngine(_SPEC, _SPEC,
                                   ShardSpec(tp_src, _SPEC.num_kv_heads),
                                   ShardSpec(tp_dst, _SPEC.num_kv_heads))
    plan = engine.plan("flowkv", src_blocks, dst_blocks)
    dst_pools = engine.execute(plan, _shard_pool(src_full, tp_src),
                               _shard_pool(dst_full, tp_dst))

    expected = sharded_transfer_calls(tp_src, tp_dst)
    assert engine.num_dispatches == expected
    assert plan.num_dispatches == expected
    assert len(shard_pairs(engine.src_shard, engine.dst_shard)) == expected
    np.testing.assert_array_equal(_unshard_pool(dst_pools, tp_dst),
                                  np.asarray(ref_dst))
    # per-pair bytes partition the unsharded plan's bytes exactly
    assert plan.total_bytes == ref_plan.total_bytes
    assert verify_sharded_transfer(plan, _SPEC,
                                   _shard_pool(src_full, tp_src),
                                   _SPEC, dst_pools)


def test_per_pair_single_dispatch_accumulates():
    """num_dispatches grows by exactly the pair count per executed plan —
    the per-shard-pair single-dispatch invariant over repeated transfers."""
    engine = ShardedTransferEngine(_SPEC, _SPEC,
                                   ShardSpec(4, _SPEC.num_kv_heads),
                                   ShardSpec(2, _SPEC.num_kv_heads))
    src_pools = _shard_pool(_full_pool(3), 4)
    dst_pools = _shard_pool(_full_pool(4), 2)
    per_plan = sharded_transfer_calls(4, 2)
    for i in range(3):
        plan = engine.plan("flowkv", [i, i + 4], [i + 1, i + 5])
        dst_pools = engine.execute(plan, src_pools, dst_pools)
        assert engine.num_dispatches == (i + 1) * per_plan


def test_verify_sharded_transfer_catches_corruption():
    src_full = _full_pool(seed=20)
    engine = ShardedTransferEngine(_SPEC, _SPEC,
                                   ShardSpec(2, _SPEC.num_kv_heads),
                                   ShardSpec(1, _SPEC.num_kv_heads))
    plan = engine.plan("flowkv", [0, 1], [2, 3])
    dst_pools = engine.execute(plan, _shard_pool(src_full, 2),
                               [_full_pool(seed=21)])
    src_pools = _shard_pool(src_full, 2)
    assert verify_sharded_transfer(plan, _SPEC, src_pools, _SPEC, dst_pools)
    # flip one element inside a page the plan wrote -> per-pair digest fails
    bad = dst_pools[0].reshape(-1, _SPEC.payload)
    table = plan.to_descriptors()
    pid = int(table.page_ids(_SPEC, "dst")[0])
    bad = bad.at[pid, 0].add(1.0).reshape(dst_pools[0].shape)
    assert not verify_sharded_transfer(plan, _SPEC, src_pools, _SPEC, [bad])


# ---------------------------------------------------------------------------
# fault plane through sharded pools
# ---------------------------------------------------------------------------
def test_sharded_transfer_corruption_repaired_by_retry(dense_model):
    """Injected in-flight corruption on a sharded hop: the per-pair checksum
    catches it, the retry re-executes (repairs), tokens stay exact."""
    from repro.faults import FaultSpec
    cfg, params = dense_model
    prompts = _prompts(cfg, n=2, seed=6)
    refs = _reference(cfg, params, prompts, steps=4)
    cluster, done = _run_cluster(
        cfg, params, prompts, 4, tp_degrees={0: 2, 1: 1},
        faults=[FaultSpec("transfer_corrupt", at=0.0, count=2)])
    for r in done:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)]
    stats = cluster.stats()
    assert stats["transfer_retries"] >= 1
    assert stats["degraded_to_recompute"] == 0
    assert stats["leaked_blocks"] == 0.0


def test_shard_aware_leak_audit_under_node_kill(dense_model):
    """Kill the TP=1 decode node while requests are in flight: recovery
    re-prefills on the surviving TP=2 node, every request terminates, and
    the fleet-wide block audit (including the sharded pool's shared block
    manager) reports zero leaks."""
    cfg, params = dense_model
    prompts = _prompts(cfg, n=3, seed=8)
    refs = _reference(cfg, params, prompts, steps=4)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, tp_degrees={0: 2, 1: 1},
                        heartbeat_timeout_cycles=2.0)
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=4))
            for p in prompts]
    for r in reqs:
        cluster.submit(r)
    for _ in range(2):           # get KV in flight before the kill
        cluster.step()
    cluster.kill_node(1)         # the decode side dies mid-run
    cluster.run([], max_cycles=120)
    assert len(cluster.finished) == len(prompts)
    for r in cluster.finished:
        # recovery replays already-emitted tokens teacher-forced (they are
        # appended to prompt_tokens), so match requests back to their
        # ORIGINAL prompt by prefix — the output stream must still be the
        # exact greedy continuation
        p = next(pp for pp in prompts
                 if list(r.prompt_tokens[:len(pp)]) == list(pp))
        assert [int(t) for t in r.output_tokens] == refs[tuple(p)]
    assert cluster.audit_blocks() == 0
    cluster.assert_no_leaks()
    # the shared block manager behind the sharded pool stays coherent
    for shard in cluster.engines[0].kv.shards:
        assert shard.bm is cluster.engines[0].kv.bm
    cluster.engines[0].kv.check_invariants()
