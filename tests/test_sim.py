"""Simulator: paper-trend assertions (FlowKV's wins must emerge from the
real control plane + calibrated costs, not be hard-coded)."""
import pytest

from repro.configs import get_config
from repro.sim.cluster_sim import ClusterSim
from repro.sim.hardware import H20, L20
from repro.sim.workload import LONGBENCH, SIMULATED, generate


@pytest.fixture(scope="module")
def cfg8b():
    return get_config("llama31-8b")


def _run(cfg, kind, wl="10k", rps=1.0, **kw):
    sim = ClusterSim(cfg, kind, **kw)
    return sim.run(generate(SIMULATED[wl], rps=rps, seed=0), t_max=50_000)


def test_flowkv_beats_vllm_disagg_at_load(cfg8b):
    fk = _run(cfg8b, "flowkv")
    vd = _run(cfg8b, "vllm_disagg")
    assert fk["finished"] == vd["finished"] == 100
    assert fk["throughput_tok_s"] > 1.2 * vd["throughput_tok_s"]
    assert fk["mean_transfer_calls"] == 1.0
    assert vd["mean_transfer_calls"] == 64.0          # 2L for llama31-8b


def test_flowkv_transfer_latency_negligible(cfg8b):
    fk = _run(cfg8b, "flowkv")
    # paper: ~0.053 s average; must be well under 100 ms at 10k ctx
    assert fk["mean_transfer_s"] < 0.1
    vd = _run(cfg8b, "vllm_disagg")
    assert vd["mean_transfer_s"] > 10 * fk["mean_transfer_s"]


def test_distserve_saturates_on_long_prompts(cfg8b):
    mid = _run(cfg8b, "distserve", rps=1.0)
    hi = _run(cfg8b, "distserve", rps=2.0)
    # saturation plateau: doubling RPS past saturation changes nothing
    assert abs(hi["throughput_tok_s"] - mid["throughput_tok_s"]) \
        < 0.2 * mid["throughput_tok_s"]
    fk = _run(cfg8b, "flowkv", rps=2.0)
    assert fk["throughput_tok_s"] > 1.4 * hi["throughput_tok_s"]


def test_colocated_tpot_degrades_under_long_prefill(cfg8b):
    colo = _run(cfg8b, "vllm_colocated", rps=1.0)
    disagg = _run(cfg8b, "flowkv", rps=1.0)
    assert colo["mean_tpot_s"] > disagg["mean_tpot_s"]


def test_heterogeneous_placement_gain(cfg8b):
    wl = LONGBENCH["gov_report"]
    good = ClusterSim(cfg8b, "flowkv", num_prefill=4, num_decode=4,
                      hw_prefill=L20, hw_decode=H20, same_host=False)
    g = good.run(generate(wl, rps=0.5, seed=1), t_max=50_000)
    bad = ClusterSim(cfg8b, "flowkv", num_prefill=4, num_decode=4,
                     hw_prefill=H20, hw_decode=L20, same_host=False)
    b = bad.run(generate(wl, rps=0.5, seed=1), t_max=50_000)
    assert g["mean_e2e_s"] < b["mean_e2e_s"], (g["mean_e2e_s"], b["mean_e2e_s"])


def test_role_switch_fires_under_imbalance(cfg8b):
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=1, num_decode=3)
    stats = sim.run(generate(SIMULATED["10k"], rps=2.0, seed=0), t_max=50_000)
    kinds = {e.kind for e in sim.controller.events}
    assert "role_switch" in kinds or "regime" in kinds


def test_sim_dispatch_counts_from_descriptor_tables(cfg8b):
    """The simulator's dispatch metric comes from the same descriptor tables
    the real executor runs: one dispatch per transfer, every system."""
    for kind in ("flowkv", "vllm_disagg"):
        stats = _run(cfg8b, kind)
        assert stats["mean_transfer_dispatches"] == 1.0, kind
