"""Simulator: paper-trend assertions (FlowKV's wins must emerge from the
real control plane + calibrated costs, not be hard-coded)."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.sim.cluster_sim import ROUTING_POLICIES, ClusterSim
from repro.sim.hardware import A100, H20, L20
from repro.sim.scenarios import SCENARIOS, get_scenario
from repro.sim.workload import (LONGBENCH, SIMULATED, WorkloadSpec, generate,
                                generate_mixture)


@pytest.fixture(scope="module")
def cfg8b():
    return get_config("llama31-8b")


def _run(cfg, kind, wl="10k", rps=1.0, **kw):
    sim = ClusterSim(cfg, kind, **kw)
    return sim.run(generate(SIMULATED[wl], rps=rps, seed=0), t_max=50_000)


def test_flowkv_beats_vllm_disagg_at_load(cfg8b):
    fk = _run(cfg8b, "flowkv")
    vd = _run(cfg8b, "vllm_disagg")
    assert fk["finished"] == vd["finished"] == 100
    assert fk["throughput_tok_s"] > 1.2 * vd["throughput_tok_s"]
    assert fk["mean_transfer_calls"] == 1.0
    assert vd["mean_transfer_calls"] == 64.0          # 2L for llama31-8b


def test_flowkv_transfer_latency_negligible(cfg8b):
    fk = _run(cfg8b, "flowkv")
    # paper: ~0.053 s average; must be well under 100 ms at 10k ctx
    assert fk["mean_transfer_s"] < 0.1
    vd = _run(cfg8b, "vllm_disagg")
    assert vd["mean_transfer_s"] > 10 * fk["mean_transfer_s"]


def test_distserve_saturates_on_long_prompts(cfg8b):
    mid = _run(cfg8b, "distserve", rps=1.0)
    hi = _run(cfg8b, "distserve", rps=2.0)
    # saturation plateau: doubling RPS past saturation changes nothing
    assert abs(hi["throughput_tok_s"] - mid["throughput_tok_s"]) \
        < 0.2 * mid["throughput_tok_s"]
    fk = _run(cfg8b, "flowkv", rps=2.0)
    assert fk["throughput_tok_s"] > 1.4 * hi["throughput_tok_s"]


def test_colocated_tpot_degrades_under_long_prefill(cfg8b):
    colo = _run(cfg8b, "vllm_colocated", rps=1.0)
    disagg = _run(cfg8b, "flowkv", rps=1.0)
    assert colo["mean_tpot_s"] > disagg["mean_tpot_s"]


def test_heterogeneous_placement_gain(cfg8b):
    wl = LONGBENCH["gov_report"]
    good = ClusterSim(cfg8b, "flowkv", num_prefill=4, num_decode=4,
                      hw_prefill=L20, hw_decode=H20, same_host=False)
    g = good.run(generate(wl, rps=0.5, seed=1), t_max=50_000)
    bad = ClusterSim(cfg8b, "flowkv", num_prefill=4, num_decode=4,
                     hw_prefill=H20, hw_decode=L20, same_host=False)
    b = bad.run(generate(wl, rps=0.5, seed=1), t_max=50_000)
    assert g["mean_e2e_s"] < b["mean_e2e_s"], (g["mean_e2e_s"], b["mean_e2e_s"])


def test_role_switch_fires_under_imbalance(cfg8b):
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=1, num_decode=3)
    stats = sim.run(generate(SIMULATED["10k"], rps=2.0, seed=0), t_max=50_000)
    kinds = {e.kind for e in sim.controller.events}
    assert "role_switch" in kinds or "regime" in kinds


def test_sim_dispatch_counts_from_descriptor_tables(cfg8b):
    """The simulator's dispatch metric comes from the same descriptor tables
    the real executor runs: one dispatch per transfer, every system."""
    for kind in ("flowkv", "vllm_disagg"):
        stats = _run(cfg8b, kind)
        assert stats["mean_transfer_dispatches"] == 1.0, kind


# ---------------------------------------------------------------------------
# scenario suite plumbing (benchmarks/scenarios.py runs the full gates)
# ---------------------------------------------------------------------------
def test_baseline_routing_policies_are_passive(cfg8b):
    """round_robin / static_pd must not leak load-aware behavior."""
    wl = dataclasses.replace(SIMULATED["1k"], num_requests=20)
    for routing in ("round_robin", "static_pd"):
        sim = ClusterSim(cfg8b, "flowkv", num_prefill=1, num_decode=3,
                         routing=routing)
        assert not sim.controller.actions_enabled
        stats = sim.run(generate(wl, rps=3.0, seed=0), t_max=20_000)
        assert stats["finished"] == 20
        kinds = {e.kind for e in sim.controller.events}
        assert "role_switch" not in kinds and "set_role" not in kinds
    with pytest.raises(ValueError, match="routing"):
        ClusterSim(cfg8b, "flowkv", routing="bogus")


def test_round_robin_rotates_both_sides(cfg8b):
    wl = dataclasses.replace(SIMULATED["1k"], num_requests=8)
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=2, num_decode=2,
                     routing="round_robin")
    sim.run(generate(wl, rps=0.2, seed=0), t_max=20_000)
    assert all(n.served_prefill + n.served_decode > 0
               for n in sim.nodes.values())


def test_hw_nodes_mixed_fleet_and_length_check(cfg8b):
    sim = ClusterSim(cfg8b, "flowkv", num_prefill=2, num_decode=2,
                     hw_nodes=(A100, L20, A100, H20))
    assert sim.nodes[1].hw is L20 and sim.nodes[3].hw is H20
    caps = sim.controller._capabilities()
    assert caps[0] == (1.0, pytest.approx(0.5), pytest.approx(80 / 96))
    with pytest.raises(ValueError, match="hw_nodes"):
        ClusterSim(cfg8b, "flowkv", num_prefill=1, num_decode=1,
                   hw_nodes=(A100,))


def test_generate_mixture_draws_from_both_specs():
    heavy = WorkloadSpec("h", 4096, 16)
    light = WorkloadSpec("l", 64, 256)
    reqs = generate_mixture([heavy, light], [0.5, 0.5], rps=1.0,
                            num_requests=60, seed=3)
    assert len(reqs) == 60
    lens = {r.prompt_len > 1000 for r in reqs}
    assert lens == {True, False}, "mixture never drew one of the specs"
    assert all(reqs[i].arrival_time <= reqs[i + 1].arrival_time
               for i in range(len(reqs) - 1))


def test_overload_scenario_gate_smoke():
    """Overload scenario: the admission gate fires for the load-aware
    policy and goodput/p95 beat the naive baseline (same gate CI's
    scenario-smoke job runs through benchmarks/scenarios.py --check)."""
    sc = get_scenario("overload")
    la = sc.run("load_aware")
    rr = sc.run("round_robin")
    assert la["rejected"] > 0
    assert rr["rejected"] == 0
    assert la["goodput"] >= rr["goodput"]
    assert la["p95_ttft_s"] <= rr["p95_ttft_s"]
    assert la["finished"] + la["rejected"] == la["offered"] == sc.num_requests


def test_scenario_registry_complete():
    assert set(SCENARIOS) == {"normal", "imbalance", "overload",
                              "heterogeneous", "failure", "multiturn",
                              "sharded_heterogeneous"}
    for name, sc in SCENARIOS.items():
        assert sc.name == name and sc.description
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope")
