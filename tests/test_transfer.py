"""Transfer planner/engine: correctness across layouts, schedules, dtypes;
call-count formulas; Table-3 cost-model calibration."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout as L
from repro.core.costmodel import IPC, NCCL_INTRA, VLLM_MERGE_INTRA, get_profile
from repro.core.transfer import TransferPlanner, transfer_request


def _spec(layout=L.KVLayout.FLOWKV, dtype=jnp.float32, layers=3):
    return L.KVCacheSpec(num_layers=layers, num_blocks=24, block_size=4,
                         num_kv_heads=2, head_dim=8, dtype=dtype, layout=layout)


@pytest.mark.parametrize("schedule", ["flowkv", "layerwise", "blockwise"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_transfer_executes_exactly(schedule, dtype):
    spec = _spec(dtype=dtype)
    if schedule == "flowkv":
        src_spec = dst_spec = spec
    else:
        src_spec = dst_spec = spec
    rng = np.random.RandomState(0)
    src = jnp.asarray(rng.randn(*src_spec.shape), dtype)
    dst = jnp.zeros(dst_spec.shape, dtype)
    sb, db = [3, 4, 5, 11], [7, 8, 9, 2]
    out, plan, lat = transfer_request(src_spec, src, sb, dst_spec, dst, db,
                                      schedule, get_profile("nccl"))
    np.testing.assert_array_equal(np.asarray(out)[np.array(db)],
                                  np.asarray(src)[np.array(sb)])
    assert lat > 0


def test_cross_layout_transfer():
    """P node on FlowKV layout -> D node on vLLM layout still lands exactly."""
    src_spec = _spec(L.KVLayout.FLOWKV)
    dst_spec = _spec(L.KVLayout.VLLM)
    rng = np.random.RandomState(1)
    src = jnp.asarray(rng.randn(*src_spec.shape), jnp.float32)
    dst = jnp.zeros(dst_spec.shape, jnp.float32)
    sb, db = [0, 1, 2], [5, 6, 7]
    out, plan, _ = transfer_request(src_spec, src, sb, dst_spec, dst, db, "layerwise")
    for layer in range(3):
        for s, d in zip(sb, db):
            np.testing.assert_array_equal(
                np.asarray(out)[layer, 0, d], np.asarray(src)[s, layer, 0])


def test_call_count_formulas():
    spec = _spec(layers=5)
    planner = TransferPlanner(spec)
    n = 7
    ids = list(range(n))
    assert planner.plan_layerwise(ids, ids).num_calls == 2 * 5 * n
    assert planner.plan_blockwise(ids, ids).num_calls == 2 * 5
    assert planner.plan_flowkv(ids, ids).num_calls == 1
    scattered_dst = [10, 3, 7, 1, 20, 15, 8]
    assert planner.plan_flowkv(ids, scattered_dst).num_calls == n


def test_flowkv_schedule_requires_flowkv_layout():
    planner = TransferPlanner(_spec(L.KVLayout.VLLM))
    with pytest.raises(ValueError):
        planner.plan_flowkv([0], [0])


def test_bytes_conservation():
    spec = _spec()
    planner = TransferPlanner(spec)
    ids = list(range(6))
    total = 6 * spec.bytes_per_block
    assert planner.plan_flowkv(ids, ids).total_bytes == total
    assert planner.plan_layerwise(ids, ids).total_bytes == total


# ---------------------------------------------------------------------------
# Table-3 calibration: model must reproduce the paper's numbers within 25 %
# ---------------------------------------------------------------------------
TABLE3_SINGLE = {  # tokens -> (vllm_disagg, flowkv_layerwise, flowkv)
    1000: (0.2314, 0.1309, 0.0075),
    4000: (0.6670, 0.5338, 0.0236),
    8000: (1.3382, 1.1173, 0.0447),
    12000: (2.1894, 1.7218, 0.0681),
}


def test_costmodel_matches_table3():
    from repro.configs import get_config
    cfg = get_config("llama31-8b")
    spec = L.KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                         block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                         head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    for tokens, (p_vllm, p_lw, p_fk) in TABLE3_SINGLE.items():
        ids = list(range(spec.blocks_for_tokens(tokens)))
        lat_fk = planner.plan_flowkv(ids, ids).latency(IPC)
        lat_lw = planner.plan_layerwise(ids, ids).latency(NCCL_INTRA)
        lat_bw = planner.plan_blockwise(ids, ids).latency(VLLM_MERGE_INTRA)
        assert abs(lat_fk - p_fk) / p_fk < 0.25, (tokens, lat_fk, p_fk)
        assert abs(lat_lw - p_lw) / p_lw < 0.25, (tokens, lat_lw, p_lw)
        assert abs(lat_bw - p_vllm) / p_vllm < 0.30, (tokens, lat_bw, p_vllm)


def test_calls_per_request_headline():
    """Paper: 23,469 layerwise calls -> 1 FlowKV call at ~11.7k ctx."""
    from repro.configs import get_config
    cfg = get_config("llama31-8b")
    spec = L.KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                         block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                         head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    ids = list(range(spec.blocks_for_tokens(11700)))
    lw = planner.plan_layerwise(ids, ids).num_calls
    assert abs(lw - 23469) / 23469 < 0.01
    assert planner.plan_flowkv(ids, ids).num_calls == 1


# ---------------------------------------------------------------------------
# Fused descriptor-table data plane
# ---------------------------------------------------------------------------
def _per_op_oracle(src_spec, dst_spec, src, dst, src_blocks, dst_blocks):
    """The OLD per-(layer, kv, block) Python-loop path, kept as the oracle the
    fused single-dispatch executor must match bit-exactly."""
    out = np.array(dst)
    s = np.asarray(src)
    for bs, bd in zip(src_blocks, dst_blocks):
        for layer in range(src_spec.num_layers):
            for kv in (0, 1):
                if src_spec.layout is L.KVLayout.FLOWKV:
                    page = s[bs, layer, kv]
                else:
                    page = s[layer, kv, bs]
                if dst_spec.layout is L.KVLayout.FLOWKV:
                    out[bd, layer, kv] = page.astype(out.dtype)
                else:
                    out[layer, kv, bd] = page.astype(out.dtype)
    return out


@pytest.mark.parametrize("schedule", ["flowkv", "layerwise", "blockwise"])
@pytest.mark.parametrize("src_layout,dst_layout", [
    (L.KVLayout.FLOWKV, L.KVLayout.FLOWKV),
    (L.KVLayout.FLOWKV, L.KVLayout.VLLM),
    (L.KVLayout.VLLM, L.KVLayout.FLOWKV),
])
def test_fused_executor_matches_per_op_path(schedule, src_layout, dst_layout):
    """One fused dispatch == the old per-op loop, for every schedule, across
    heterogeneous pool sizes and non-identity scattered dst placements."""
    if schedule == "flowkv" and src_layout is not L.KVLayout.FLOWKV:
        pytest.skip("flowkv schedule requires the FLOWKV planner layout")
    src_spec = _spec(src_layout)
    dst_spec = L.KVCacheSpec(num_layers=3, num_blocks=40, block_size=4,
                             num_kv_heads=2, head_dim=8, dtype=jnp.float32,
                             layout=dst_layout)   # heterogeneous block count
    rng = np.random.RandomState(7)
    src = jnp.asarray(rng.randn(*src_spec.shape), jnp.float32)
    dst0 = jnp.asarray(rng.randn(*dst_spec.shape), jnp.float32)
    sb = [3, 4, 5, 11, 12]
    db = [31, 2, 17, 8, 9]                         # non-identity, scattered
    out, plan, _ = transfer_request(src_spec, src, sb, dst_spec, dst0, db, schedule)
    expect = _per_op_oracle(src_spec, dst_spec, src, dst0, sb, db)
    np.testing.assert_array_equal(np.asarray(out), expect)
    assert plan.num_dispatches == 1


def test_every_schedule_is_one_dispatch():
    """The acceptance invariant: no per-block Python loop survives — each
    schedule executes its whole plan as exactly ONE executor invocation."""
    from repro.core import transfer as TR
    spec = _spec()
    rng = np.random.RandomState(3)
    src = jnp.asarray(rng.randn(*spec.shape), jnp.float32)
    sb, db = [1, 2, 3, 9, 15], [20, 21, 22, 4, 10]
    for schedule in ("layerwise", "blockwise", "flowkv"):
        engine = TR.TransferEngine(spec)
        plan = engine.planner.plan(schedule, sb, db)
        before = TR.total_dispatches()
        engine.execute(plan, src, jnp.zeros(spec.shape, jnp.float32))
        assert TR.total_dispatches() - before == 1, schedule
        assert engine.num_dispatches == 1, schedule
        assert plan.num_dispatches == 1


def test_plan_blockwise_empty_returns_empty_plan():
    """No fabricated Segment(0, 1) bookkeeping for ranges never allocated."""
    planner = TransferPlanner(_spec())
    plan = planner.plan_blockwise([], [])
    assert plan.ops == []
    assert plan.num_calls == 0
    assert plan.total_bytes == 0
    assert plan.num_blocks == 0
    assert plan.num_dispatches == 0
    # executing an empty plan is a data-plane no-op (zero dispatches)
    from repro.core.transfer import TransferEngine
    spec = _spec()
    engine = TransferEngine(spec)
    dst = jnp.ones(spec.shape, jnp.float32)
    out = engine.execute(plan, jnp.zeros(spec.shape, jnp.float32), dst)
    assert engine.num_dispatches == 0
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dst))


def test_descriptor_table_lowering():
    """Descriptor counts/pages are exact and num_calls is derived from the
    same table the executor runs."""
    spec = _spec(layers=3)
    planner = TransferPlanner(spec)
    sb, db = [5, 6, 9], [2, 3, 7]
    for schedule, calls in (("layerwise", 2 * 3 * 3), ("blockwise", 2 * 3),
                            ("flowkv", 2)):
        plan = planner.plan(schedule, sb, db)
        table = plan.to_descriptors()
        assert len(table) == 3 * 2 * 3            # n * 2 * L pages, always
        assert table.num_calls(schedule) == calls
        assert plan.num_calls == calls
        # page ids must be in range and dst pages unique within a plan
        src_pages = table.page_ids(spec, "src")
        dst_pages = table.page_ids(spec, "dst")
        assert src_pages.min() >= 0
        assert src_pages.max() < spec.num_blocks * spec.num_layers * 2
        assert len(np.unique(dst_pages)) == len(table)


def test_fused_executor_untouched_blocks_preserved():
    """Pages outside the descriptor table keep their previous contents."""
    spec = _spec()
    rng = np.random.RandomState(5)
    src = jnp.asarray(rng.randn(*spec.shape), jnp.float32)
    dst0 = jnp.asarray(rng.randn(*spec.shape), jnp.float32)
    sb, db = [0, 1], [5, 6]
    out, _, _ = transfer_request(spec, src, sb, spec, dst0, db, "flowkv")
    untouched = [i for i in range(spec.num_blocks) if i not in db]
    np.testing.assert_array_equal(np.asarray(out)[untouched],
                                  np.asarray(dst0)[untouched])
