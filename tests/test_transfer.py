"""Transfer planner/engine: correctness across layouts, schedules, dtypes;
call-count formulas; Table-3 cost-model calibration."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout as L
from repro.core.costmodel import IPC, NCCL_INTRA, VLLM_MERGE_INTRA, get_profile
from repro.core.transfer import TransferPlanner, transfer_request


def _spec(layout=L.KVLayout.FLOWKV, dtype=jnp.float32, layers=3):
    return L.KVCacheSpec(num_layers=layers, num_blocks=24, block_size=4,
                         num_kv_heads=2, head_dim=8, dtype=dtype, layout=layout)


@pytest.mark.parametrize("schedule", ["flowkv", "layerwise", "blockwise"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_transfer_executes_exactly(schedule, dtype):
    spec = _spec(dtype=dtype)
    if schedule == "flowkv":
        src_spec = dst_spec = spec
    else:
        src_spec = dst_spec = spec
    rng = np.random.RandomState(0)
    src = jnp.asarray(rng.randn(*src_spec.shape), dtype)
    dst = jnp.zeros(dst_spec.shape, dtype)
    sb, db = [3, 4, 5, 11], [7, 8, 9, 2]
    out, plan, lat = transfer_request(src_spec, src, sb, dst_spec, dst, db,
                                      schedule, get_profile("nccl"))
    np.testing.assert_array_equal(np.asarray(out)[np.array(db)],
                                  np.asarray(src)[np.array(sb)])
    assert lat > 0


def test_cross_layout_transfer():
    """P node on FlowKV layout -> D node on vLLM layout still lands exactly."""
    src_spec = _spec(L.KVLayout.FLOWKV)
    dst_spec = _spec(L.KVLayout.VLLM)
    rng = np.random.RandomState(1)
    src = jnp.asarray(rng.randn(*src_spec.shape), jnp.float32)
    dst = jnp.zeros(dst_spec.shape, jnp.float32)
    sb, db = [0, 1, 2], [5, 6, 7]
    out, plan, _ = transfer_request(src_spec, src, sb, dst_spec, dst, db, "layerwise")
    for layer in range(3):
        for s, d in zip(sb, db):
            np.testing.assert_array_equal(
                np.asarray(out)[layer, 0, d], np.asarray(src)[s, layer, 0])


def test_call_count_formulas():
    spec = _spec(layers=5)
    planner = TransferPlanner(spec)
    n = 7
    ids = list(range(n))
    assert planner.plan_layerwise(ids, ids).num_calls == 2 * 5 * n
    assert planner.plan_blockwise(ids, ids).num_calls == 2 * 5
    assert planner.plan_flowkv(ids, ids).num_calls == 1
    scattered_dst = [10, 3, 7, 1, 20, 15, 8]
    assert planner.plan_flowkv(ids, scattered_dst).num_calls == n


def test_flowkv_schedule_requires_flowkv_layout():
    planner = TransferPlanner(_spec(L.KVLayout.VLLM))
    with pytest.raises(ValueError):
        planner.plan_flowkv([0], [0])


def test_bytes_conservation():
    spec = _spec()
    planner = TransferPlanner(spec)
    ids = list(range(6))
    total = 6 * spec.bytes_per_block
    assert planner.plan_flowkv(ids, ids).total_bytes == total
    assert planner.plan_layerwise(ids, ids).total_bytes == total


# ---------------------------------------------------------------------------
# Table-3 calibration: model must reproduce the paper's numbers within 25 %
# ---------------------------------------------------------------------------
TABLE3_SINGLE = {  # tokens -> (vllm_disagg, flowkv_layerwise, flowkv)
    1000: (0.2314, 0.1309, 0.0075),
    4000: (0.6670, 0.5338, 0.0236),
    8000: (1.3382, 1.1173, 0.0447),
    12000: (2.1894, 1.7218, 0.0681),
}


def test_costmodel_matches_table3():
    from repro.configs import get_config
    cfg = get_config("llama31-8b")
    spec = L.KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                         block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                         head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    for tokens, (p_vllm, p_lw, p_fk) in TABLE3_SINGLE.items():
        ids = list(range(spec.blocks_for_tokens(tokens)))
        lat_fk = planner.plan_flowkv(ids, ids).latency(IPC)
        lat_lw = planner.plan_layerwise(ids, ids).latency(NCCL_INTRA)
        lat_bw = planner.plan_blockwise(ids, ids).latency(VLLM_MERGE_INTRA)
        assert abs(lat_fk - p_fk) / p_fk < 0.25, (tokens, lat_fk, p_fk)
        assert abs(lat_lw - p_lw) / p_lw < 0.25, (tokens, lat_lw, p_lw)
        assert abs(lat_bw - p_vllm) / p_vllm < 0.30, (tokens, lat_bw, p_vllm)


def test_calls_per_request_headline():
    """Paper: 23,469 layerwise calls -> 1 FlowKV call at ~11.7k ctx."""
    from repro.configs import get_config
    cfg = get_config("llama31-8b")
    spec = L.KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                         block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                         head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    ids = list(range(spec.blocks_for_tokens(11700)))
    lw = planner.plan_layerwise(ids, ids).num_calls
    assert abs(lw - 23469) / 23469 < 0.01
    assert planner.plan_flowkv(ids, ids).num_calls == 1
