"""Prefix-cache reuse: a hit must SKIP work, not just change accounting.

Covers the shared-block data plane end to end:

* stable hashing — index digests are identical across interpreter hash
  seeds (checkpoint/restore and cross-process state stay meaningful);
* residency honesty — entries die with their backing blocks (every free
  path) and re-home to the decode node after the P->D transfer;
* refcounted sharing — donor/sharer free in either order without leaks,
  audited by ``BlockManager.check_invariants``;
* accounting — with a warm prefix of length L, prefill executes exactly
  ``prompt_len - L`` tokens (counter-verified) and the scheduler's progress
  never double-counts the cached prefix;
* token identity — outputs with reuse on (local hit AND remote fetch) are
  bit-identical to cold prefill, and a remote fetch is ONE fused
  descriptor-table dispatch.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.block_manager import BlockManager
from repro.core.scheduler.hybrid_scheduler import HybridScheduler
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.cluster import PDCluster
from repro.serving.prefix_cache import PrefixCacheIndex, _block_hashes
from repro.serving.request import Request, SamplingParams
from repro.sim.hardware import TPU_V5E

# recompute must look expensive for the router to pick fetch-over-recompute
# on the smoke-scale model (the cost model is honest: at 2 layers the real
# break-even favors recompute, which is exactly what we DON'T want to test)
WEAK = dataclasses.replace(TPU_V5E, peak_flops=1e6)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _shared_prompts(cfg, n_followers=2, prefix_len=64, seed=7):
    rng = np.random.RandomState(seed)
    prefix = rng.randint(0, cfg.vocab_size, size=prefix_len).tolist()
    donor = prefix + rng.randint(0, cfg.vocab_size, size=10).tolist()
    followers = [prefix + rng.randint(0, cfg.vocab_size, size=5 + 3 * i).tolist()
                 for i in range(n_followers)]
    return donor, followers


def _reference(cfg, params, prompts, steps):
    return {tuple(p): [int(x) for x in
                       T.greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), steps)[0]]
            for p in prompts}


def _run_staggered(cfg, params, donor, followers, steps=5, **kw):
    """Donor first; followers submitted once the donor's KV is resident."""
    cluster = PDCluster(cfg, params, num_blocks=128, max_batch_tokens=4096, **kw)
    reqs = [Request(prompt_tokens=list(p), sampling=SamplingParams(max_new_tokens=steps))
            for p in [donor] + followers]
    cluster.submit(reqs[0])
    for _ in range(3):
        cluster.step()
    for r in reqs[1:]:
        cluster.submit(r)
    for _ in range(120):
        cluster.step()
        if len(cluster.finished) == len(reqs):
            break
    assert len(cluster.finished) == len(reqs)
    for e in cluster.engines.values():
        e.scheduler.bm.check_invariants()
    outs = {tuple(r.prompt_tokens): list(r.output_tokens) for r in cluster.finished}
    return cluster, reqs, outs


# ---------------------------------------------------------------------------
# stable hashing
# ---------------------------------------------------------------------------
def test_block_hashes_stable_across_hash_seeds():
    """Digests must not depend on the interpreter's hash salt — run the
    chain under two different PYTHONHASHSEEDs and compare."""
    snippet = (
        "from repro.serving.prefix_cache import _block_hashes;"
        "print([h.hex() for h in _block_hashes(list(range(40)), 8)])"
    )
    outs = []
    for seed in ("0", "424242"):
        env = dict(os.environ, PYTHONHASHSEED=seed,
                   PYTHONPATH=os.pathsep.join(
                       [os.path.join(os.path.dirname(__file__), "..", "src"),
                        os.environ.get("PYTHONPATH", "")]))
        outs.append(subprocess.run(
            [sys.executable, "-c", snippet], env=env, capture_output=True,
            text=True, check=True).stdout.strip())
    assert outs[0] == outs[1]
    assert outs[0] == repr([h.hex() for h in _block_hashes(list(range(40)), 8)])


def test_block_hashes_are_a_chain():
    """hash(i) covers the whole prefix, not just block i: the same block
    content at a different chain position must hash differently."""
    a = _block_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = _block_hashes([9, 9, 9, 9, 5, 6, 7, 8], 4)
    assert len(a) == len(b) == 2
    assert a[1] != b[1]          # same 2nd block, different prefix


# ---------------------------------------------------------------------------
# index residency
# ---------------------------------------------------------------------------
def test_index_lookup_blocks_and_invalidation():
    idx = PrefixCacheIndex(block_size=4)
    idx.insert(0, list(range(12)), block_ids=[7, 8, 9])
    m = idx.lookup(0, list(range(12)))
    assert (m.num_tokens, m.block_ids) == (12, [7, 8, 9])
    # freeing the middle block truncates the shareable chain at the break
    idx.invalidate_blocks(0, [8])
    m = idx.lookup(0, list(range(12)))
    assert (m.num_tokens, m.block_ids) == (4, [7])
    idx.evict_node(0)
    assert idx.lookup(0, list(range(12))).num_tokens == 0


def test_index_unbacked_entries_match_but_never_share():
    idx = PrefixCacheIndex(block_size=4)
    idx.insert(1, list(range(8)))                 # routing-signal only
    m = idx.lookup(1, list(range(8)))
    assert m.num_tokens == 8 and m.block_ids == []


def test_index_unbacked_insert_keeps_backed_invalidation():
    """A later unbacked insert of the same chain must not orphan the backed
    entry's block mapping — its invalidation path has to stay live."""
    idx = PrefixCacheIndex(block_size=4)
    idx.insert(0, list(range(8)), block_ids=[1, 2])
    idx.insert(0, list(range(8)))                 # routing-signal re-insert
    assert idx.lookup(0, list(range(8))).block_ids == [1, 2]
    idx.invalidate_blocks(0, [1, 2])
    assert idx.lookup(0, list(range(8))).num_tokens == 0


def test_index_reinsert_repoints_to_newest_copy():
    idx = PrefixCacheIndex(block_size=4)
    idx.insert(0, list(range(8)), block_ids=[1, 2])
    idx.insert(0, list(range(8)), block_ids=[5, 6])     # newer copy
    assert idx.lookup(0, list(range(8))).block_ids == [5, 6]
    idx.invalidate_blocks(0, [1, 2])                    # old copy dying is a no-op
    assert idx.lookup(0, list(range(8))).block_ids == [5, 6]


# ---------------------------------------------------------------------------
# refcounted sharing (BlockManager)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("allocator", ["flowkv", "freelist"])
@pytest.mark.parametrize("donor_first", [True, False])
def test_refcount_free_ordering(allocator, donor_first):
    freed = []
    bm = BlockManager(16, 4, allocator)
    bm.on_free = freed.extend
    donor = bm.allocate(1, 9)                             # 3 blocks
    bm.allocate(2, 13, prefix_blocks=donor[:2])           # share 2, +2 fresh
    bm.check_invariants()
    assert bm.refcount(donor[0]) == 2
    order = [1, 2] if donor_first else [2, 1]
    bm.free(order[0])
    bm.check_invariants()
    # shared blocks survive the first free regardless of order
    assert bm.block_alive(donor[0]) and bm.block_alive(donor[1])
    bm.free(order[1])
    bm.check_invariants()
    # refcount zero no longer frees: the blocks PARK in the LRU cache with
    # pages intact (revivable prefix hits) until capacity pressure
    assert freed == [] and bm.free_capacity == 16
    assert bm.block_alive(donor[0]) and bm.is_cached(donor[0])
    bm.reclaim_cache()
    bm.check_invariants()
    assert bm.num_free == 16 and sorted(freed) == sorted(set(freed))
    assert not bm.block_alive(donor[0])


def test_on_free_fires_only_at_physical_reclaim():
    freed = []
    bm = BlockManager(8, 4, "flowkv")
    bm.on_free = freed.extend
    a = bm.allocate(1, 8)                 # 2 blocks
    bm.allocate(2, 12, prefix_blocks=a)   # shares both, +1 fresh
    bm.free(1)
    assert freed == []                    # still held by request 2
    bm.free(2)
    assert freed == []                    # refcount zero -> parked, not freed
    assert all(bm.is_cached(b) for b in a)
    bm.reclaim_cache()                    # pressure: pages actually recycle
    assert sorted(freed) == sorted(set(freed)) and len(freed) == 3


# ---------------------------------------------------------------------------
# scheduler accounting (the double-count satellite)
# ---------------------------------------------------------------------------
def test_prefill_progress_no_double_count():
    """progress seeds at the cached length, so the engine must report only
    EXECUTED tokens; a full-prompt report overshoots the prompt."""
    bm = BlockManager(64, 32, "flowkv")
    sched = HybridScheduler(0, bm, max_batch_tokens=4096)
    donor_blocks = bm.allocate(99, 64)

    def resolve(req):
        req.num_cached_prefix_tokens = 64
        req.prefix_src_node = 0
        req.prefix_block_ids = donor_blocks
        return donor_blocks

    sched.resolve_prefix = resolve
    req = Request(prompt_tokens=list(range(80)), sampling=SamplingParams())
    sched.enqueue_prefill(req)
    decision = sched.schedule()
    # admission billed only the suffix against the token budget
    assert decision.prefill_chunks[req.request_id] == 80 - 64
    assert bm.refcount(donor_blocks[0]) == 2        # shared, not copied
    # suffix-only completion report finishes the request EXACTLY
    assert sched.prefill_progressed(req, 80 - 64)
    assert req.request_id not in sched._progress


def test_pending_remote_fetch_not_clobbered_at_admission():
    """A request whose remote-fetch plan hasn't executed yet (e.g. the
    destination pool was momentarily full) must WAIT — re-stamping it local
    at admission would silently abandon the priced plan."""
    bm = BlockManager(64, 32, "flowkv")
    sched = HybridScheduler(0, bm, max_batch_tokens=4096)
    sched.resolve_prefix = lambda req: []            # reuse plane wired
    req = Request(prompt_tokens=list(range(80)), sampling=SamplingParams())
    req.num_cached_prefix_tokens = 64
    req.prefix_src_node = 3                          # remote plan in flight
    req.prefix_block_ids = [10, 11]
    sched.enqueue_prefill(req)
    decision = sched.schedule()
    assert decision.prefill_batch == []              # waits for the fetch
    assert req.num_cached_prefix_tokens == 64        # plan intact
    bm.allocate(req.request_id, 64)                  # fetch lands the prefix
    decision = sched.schedule()
    assert decision.prefill_chunks[req.request_id] == 80 - 64


def test_restore_onto_used_cluster_resets_block_state(small_model, tmp_path):
    """Restoring a checkpoint onto a cluster that has since served traffic
    must drop the live tables/refcounts, not layer the snapshot on top."""
    from repro.serving.checkpoint import load_cluster, save_cluster

    cfg, params = small_model
    donor, _ = _shared_prompts(cfg, n_followers=0)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1, num_blocks=128)
    r1 = Request(prompt_tokens=list(donor),
                 sampling=SamplingParams(max_new_tokens=12))
    cluster.submit(r1)
    for _ in range(3):
        cluster.step()
    save_cluster(cluster, str(tmp_path / "ckpt"))
    # serve more traffic so the live block state diverges from the snapshot
    r2 = Request(prompt_tokens=list(donor[:40]),
                 sampling=SamplingParams(max_new_tokens=12))
    cluster.submit(r2)
    for _ in range(3):
        cluster.step()
    load_cluster(cluster, str(tmp_path / "ckpt"))
    for e in cluster.engines.values():
        e.scheduler.bm.check_invariants()
    # the stale request's blocks must be gone; the snapshot's must be back
    bms = [e.scheduler.bm for e in cluster.engines.values()]
    assert not any(bm.owns(r2.request_id) for bm in bms)
    assert any(bm.owns(r1.request_id) for bm in bms)
    # and the prefix index must not advertise residency recorded before the
    # restore rewrote the pools (blocks may now hold different KV)
    assert not cluster.controller.prefix_index.has_entries


def test_admission_zeroes_stamp_without_resolver():
    """No resolver wired => no reuse data plane => a routed-in stamp must
    not survive to bill compute the engine will not skip."""
    bm = BlockManager(64, 32, "flowkv")
    sched = HybridScheduler(0, bm, max_batch_tokens=4096)
    req = Request(prompt_tokens=list(range(80)), sampling=SamplingParams())
    req.num_cached_prefix_tokens = 64               # phantom routing stamp
    sched.enqueue_prefill(req)
    decision = sched.schedule()
    assert req.num_cached_prefix_tokens == 0
    assert decision.prefill_chunks[req.request_id] == 80


# ---------------------------------------------------------------------------
# token identity + counters (real compute)
# ---------------------------------------------------------------------------
def test_local_hit_token_identity_and_exact_savings(small_model):
    cfg, params = small_model
    donor, followers = _shared_prompts(cfg)
    refs = _reference(cfg, params, [donor] + followers, steps=5)
    # single hybrid node: P==D, local handoff keeps the donor's blocks
    # resident, followers share them in place
    cluster, reqs, outs = _run_staggered(cfg, params, donor, followers,
                                         num_prefill=1, num_decode=0)
    assert outs == refs
    s = cluster.stats()
    assert s["prefix_hits"] == len(followers)
    assert s["prefix_tokens_reused"] == 64 * len(followers)
    total = sum(r.prompt_len for r in reqs)
    # THE acceptance criterion: exactly prompt_len - L tokens executed
    assert s["prefill_tokens_computed"] == total - s["prefix_tokens_reused"]
    assert s["prefix_fetches"] == 0


def test_remote_fetch_token_identity_one_fused_dispatch(small_model):
    cfg, params = small_model
    donor, followers = _shared_prompts(cfg)
    refs = _reference(cfg, params, [donor] + followers, steps=5)
    # 1P + 1D: the donor's prefix re-homes to the decode node after its
    # transfer; followers must pull it back over the transfer plane
    cluster, reqs, outs = _run_staggered(cfg, params, donor, followers,
                                         num_prefill=1, num_decode=1,
                                         hardware=WEAK)
    assert outs == refs
    s = cluster.stats()
    assert s["prefix_hits"] >= 1 and s["prefix_fetches"] >= 1
    total = sum(r.prompt_len for r in reqs)
    assert s["prefill_tokens_computed"] == total - s["prefix_tokens_reused"]
    fetches = [t for t in cluster.transfers if t.kind == "prefix_fetch"]
    assert fetches and all(t.num_dispatches == 1 for t in fetches)
    fetched = [r for r in reqs[1:] if r.prefix_fetch_dispatches]
    assert fetched and all(r.prefix_fetch_dispatches == 1 for r in fetched)


def test_reuse_off_is_cold_everywhere(small_model):
    cfg, params = small_model
    donor, followers = _shared_prompts(cfg)
    refs = _reference(cfg, params, [donor] + followers, steps=5)
    cluster, reqs, outs = _run_staggered(cfg, params, donor, followers,
                                         num_prefill=1, num_decode=0,
                                         prefix_reuse=False)
    assert outs == refs
    s = cluster.stats()
    assert s["prefix_hits"] == 0 and s["prefix_tokens_reused"] == 0
    assert s["prefill_tokens_computed"] == sum(r.prompt_len for r in reqs)


def test_stale_residency_rehomes_to_decode_node(small_model):
    """After the P->D transfer the index must advertise the DECODE node and
    nothing on the prefill node (whose blocks just freed)."""
    cfg, params = small_model
    donor, _ = _shared_prompts(cfg, n_followers=0)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128)
    req = Request(prompt_tokens=list(donor),
                  sampling=SamplingParams(max_new_tokens=8))
    cluster.submit(req)
    for _ in range(4):
        cluster.step()
        if req.transfer_end is not None:
            break
    idx = cluster.controller.prefix_index
    assert idx.lookup(0, donor).num_tokens == 0        # P-side entry died
    m = idx.lookup(1, donor)
    assert m.num_tokens == 64 and len(m.block_ids) == 2
    # ... and SURVIVES decode finishing: the freed blocks park in the LRU
    # cache with pages intact, so the prefix stays advertised (the re-hit
    # satellite) until capacity pressure physically reclaims them
    for _ in range(40):
        cluster.step()
        if cluster.finished:
            break
    assert idx.lookup(1, donor).num_tokens == 64
    cluster.engines[1].scheduler.bm.reclaim_cache()
    assert idx.lookup(1, donor).num_tokens == 0


def test_cancel_while_shared_no_leak(small_model):
    """Cancelling the donor while a follower shares its blocks must neither
    free the shared blocks under the follower nor leak them after."""
    cfg, params = small_model
    donor, followers = _shared_prompts(cfg, n_followers=1)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                        num_blocks=128)
    d = Request(prompt_tokens=list(donor),
                sampling=SamplingParams(max_new_tokens=40))
    f = Request(prompt_tokens=list(followers[0]),
                sampling=SamplingParams(max_new_tokens=5))
    cluster.submit(d)
    for _ in range(3):
        cluster.step()
    cluster.submit(f)
    cluster.step()                      # follower admits, sharing the prefix
    bm = cluster.engines[0].scheduler.bm
    assert f.num_cached_prefix_tokens == 64
    shared = f.prefix_block_ids
    assert shared and all(bm.refcount(b) == 2 for b in shared)
    assert cluster.cancel(d)            # donor dies mid-decode
    bm.check_invariants()
    assert all(bm.refcount(b) == 1 for b in shared)   # follower still holds
    for _ in range(60):
        cluster.step()
        if cluster.finished:
            break
    # follower finished token-identically off the shared (now cancelled-
    # donor) prefix, and the pool drained completely
    ref = _reference(cfg, params, [followers[0]], steps=5)
    assert list(cluster.finished[0].output_tokens) == ref[tuple(followers[0])]
    bm.check_invariants()
    assert bm.free_capacity == bm.num_blocks


# ---------------------------------------------------------------------------
# sim mirror: hits priced identically
# ---------------------------------------------------------------------------
def test_sim_prices_hits_and_fetch_is_one_dispatch():
    from repro.sim.cluster_sim import ClusterSim
    from repro.sim.hardware import A100

    cfg = get_smoke_config("qwen3-1.7b")
    weak_p = dataclasses.replace(A100, peak_flops=1e7)
    weak_d = dataclasses.replace(A100, hbm_bandwidth=1e5)   # long residency
    rng = np.random.RandomState(0)
    prefix = rng.randint(0, cfg.vocab_size, size=2048).tolist()
    reqs = [Request(prompt_tokens=prefix + rng.randint(0, cfg.vocab_size, 128).tolist(),
                    sampling=SamplingParams(max_new_tokens=64),
                    arrival_time=0.0 if i == 0 else 66.0 + 0.5 * i)
            for i in range(4)]
    total = sum(r.prompt_len for r in reqs)
    sim = ClusterSim(cfg, "flowkv", num_prefill=1, num_decode=1,
                     routing="load_aware", hw_prefill=weak_p, hw_decode=weak_d)
    stats = sim.run(list(reqs), t_max=500000)
    assert stats["finished"] == len(reqs)
    assert stats["prefix_hits"] >= 1
    assert stats["prefill_tokens_computed"] == total - stats["prefix_tokens_reused"]
    assert stats["prefix_fetches"] >= 1
    assert stats["mean_prefix_fetch_dispatches"] == 1.0
    for n in sim.nodes.values():
        n.bm.check_invariants()

    # baselines never claim hits (no global prefix cache)
    sim2 = ClusterSim(cfg, "flowkv", num_prefill=1, num_decode=1,
                      routing="round_robin", hw_prefill=weak_p,
                      hw_decode=weak_d)
    reqs2 = [Request(prompt_tokens=list(r.prompt_tokens),
                     sampling=SamplingParams(max_new_tokens=64),
                     arrival_time=r.arrival_time) for r in reqs]
    stats2 = sim2.run(reqs2, t_max=500000)
    assert stats2["prefix_hits"] == 0
    assert stats2["prefill_tokens_computed"] == total


# ---------------------------------------------------------------------------
# suffix flash kernel (prefix mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,t,blk", [
    (16, 48, 16), (13, 45, 16), (48, 48, 16),
    (8, 72, 128),      # default tiles, 64 < t < 128: regression for the
                       # padded-length/tile-divisibility crash
])
def test_flash_prefill_suffix_mode_matches_oracle(s, t, blk):
    from repro.kernels.flash_prefill import flash_prefill_op, flash_prefill_ref

    b, h, kv, hd = 2, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, t, kv, hd))
    v = jax.random.normal(ks[2], (b, t, kv, hd))
    out = flash_prefill_op(q, k, v, q_blk=blk, k_blk=blk, q_offset=t - s)
    ref = flash_prefill_ref(q, k, v, q_offset=t - s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)
    # suffix rows == the corresponding rows of the full-sequence kernel
    if s < t:
        q_full = jnp.concatenate(
            [jax.random.normal(ks[0], (b, t - s, h, hd)), q], axis=1)
        full = flash_prefill_op(q_full, k, v, q_blk=blk, k_blk=blk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, t - s:]),
                                   atol=2e-6, rtol=2e-6)


def test_model_prefill_suffix_bit_identical(small_model):
    cfg, params = small_model
    model = get_model(cfg)
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, size=80).tolist()
    logits, cache = model.prefill(params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    c = 64
    sl, sc = model.prefill_suffix(
        params, {"tokens": jnp.asarray([prompt[c:]], jnp.int32)},
        cache["k"][:, :, :c], cache["v"][:, :, :c])
    assert jnp.array_equal(logits, sl)
    assert jnp.array_equal(cache["k"][:, :, c:], sc["k"])
    assert jnp.array_equal(cache["v"][:, :, c:], sc["v"])
