"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill import flash_prefill, flash_prefill_op, flash_prefill_ref
from repro.kernels.kv_gather import kv_gather, kv_gather_op, kv_gather_ref, kv_scatter_op
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_op,
                                           paged_decode_attention_ref)

SWEEP_PAGED = [
    # (B, H, KV, HD, BS, MAXB, dtype)
    (1, 4, 4, 16, 4, 3, jnp.float32),
    (3, 8, 4, 32, 8, 5, jnp.float32),
    (2, 8, 2, 64, 16, 4, jnp.float32),
    (2, 4, 1, 32, 8, 6, jnp.float32),        # MQA
    (2, 8, 4, 32, 8, 4, jnp.bfloat16),
]


@pytest.mark.parametrize("b,h,kv,hd,bs,maxb,dtype", SWEEP_PAGED)
def test_paged_attention_sweep(b, h, kv, hd, bs, maxb, dtype):
    key = jax.random.PRNGKey(0)
    nb = b * maxb + 4
    q = jax.random.normal(key, (b, h, hd), dtype)
    pages = jax.random.normal(jax.random.PRNGKey(1), (nb, 2, bs * kv * hd), dtype)
    bt = jax.random.permutation(jax.random.PRNGKey(2), nb)[:b * maxb]
    bt = bt.reshape(b, maxb).astype(jnp.int32)
    lengths = jax.random.randint(jax.random.PRNGKey(3), (b,), 1, maxb * bs + 1)
    out = paged_decode_attention(q, pages, bt, lengths, block_size=bs)
    ref = paged_decode_attention_ref(q, pages, bt, lengths, bs)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_paged_attention_op_layer_slice():
    """ops wrapper slices one layer from the full FlowKV pool."""
    b, h, kv, hd, bs, maxb, L = 2, 4, 2, 16, 4, 3, 3
    nb = 16
    pool = jax.random.normal(jax.random.PRNGKey(0), (nb, L, 2, bs * kv * hd))
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, hd))
    bt = jnp.arange(b * maxb, dtype=jnp.int32).reshape(b, maxb)
    lengths = jnp.asarray([7, 12], jnp.int32)
    for layer in range(L):
        out = paged_decode_attention_op(q, pool, layer, bt, lengths, block_size=bs)
        ref = paged_decode_attention_ref(q, pool[:, layer], bt, lengths, bs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


SWEEP_FLASH = [
    # (B, S, H, KV, HD, q_blk, k_blk, causal, dtype)
    (2, 64, 4, 2, 16, 16, 16, True, jnp.float32),
    (1, 128, 8, 8, 32, 32, 64, True, jnp.float32),     # MHA
    (2, 96, 4, 1, 16, 32, 32, True, jnp.float32),      # MQA, uneven blocks
    (2, 64, 4, 2, 16, 16, 16, False, jnp.float32),
    (2, 64, 4, 2, 32, 32, 32, True, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,kv,hd,qb,kb,causal,dtype", SWEEP_FLASH)
def test_flash_prefill_sweep(b, s, h, kv, hd, qb, kb, causal, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd), dtype)
    out = flash_prefill(q, k, v, causal=causal, q_blk=qb, k_blk=kb)
    ref = flash_prefill_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_prefill_op_pads():
    b, s, h, kv, hd = 1, 50, 2, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    out = flash_prefill_op(q, k, v, q_blk=16, k_blk=16)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [1, 5, 16])
def test_kv_gather_sweep(dtype, n):
    pool = jax.random.normal(jax.random.PRNGKey(0), (32, 3, 2, 64), dtype)
    ids = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, 32)
    out = kv_gather(pool, ids.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(kv_gather_ref(pool, ids), np.float32))


def test_kv_gather_scatter_roundtrip():
    pool = jax.random.normal(jax.random.PRNGKey(0), (32, 2, 2, 16))
    ids = jnp.asarray([4, 9, 30], jnp.int32)
    staged = kv_gather_op(pool, ids)
    dst = jnp.zeros_like(pool)
    dst = kv_scatter_op(dst, jnp.asarray([0, 1, 2], jnp.int32), staged)
    np.testing.assert_array_equal(np.asarray(dst[:3]), np.asarray(staged))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [1, 5, 16])
def test_kv_scatter_sweep(dtype, n):
    from repro.kernels.kv_gather import kv_scatter, kv_scatter_ref
    pool = jax.random.normal(jax.random.PRNGKey(0), (32, 3, 2, 64), dtype)
    staging = jax.random.normal(jax.random.PRNGKey(1), (n, 3, 2, 64), dtype)
    ids = jax.random.permutation(jax.random.PRNGKey(2), 32)[:n].astype(jnp.int32)
    out = kv_scatter(pool, ids, staging)
    ref = kv_scatter_ref(pool, ids, staging)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


@pytest.mark.parametrize("layout", ["flowkv", "vllm"])
def test_kv_gather_scatter_roundtrip_layouts(layout):
    """gather∘scatter round-trips a request's blocks on both pool layouts."""
    from repro.core import layout as L
    from repro.kernels.kv_gather import kv_scatter
    spec = L.KVCacheSpec(num_layers=3, num_blocks=16, block_size=4,
                         num_kv_heads=2, head_dim=8, dtype=jnp.float32,
                         layout=L.KVLayout.FLOWKV if layout == "flowkv"
                         else L.KVLayout.VLLM)
    pool = jax.random.normal(jax.random.PRNGKey(0), spec.shape)
    # the staging kernels are block-major: view VLLM pools through the
    # layout transform on the way in and out
    bm = pool if layout == "flowkv" else L.vllm_to_flowkv(pool)
    src_ids = jnp.asarray([2, 7, 11], jnp.int32)
    dst_ids = jnp.asarray([0, 5, 9], jnp.int32)
    staged = kv_gather(bm, src_ids)
    landed = kv_scatter(jnp.zeros_like(bm), dst_ids, staged)
    if layout == "vllm":
        landed = L.flowkv_to_vllm(landed)
        for s, d in zip([2, 7, 11], [0, 5, 9]):
            np.testing.assert_array_equal(np.asarray(landed)[:, :, d],
                                          np.asarray(pool)[:, :, s])
    else:
        for s, d in zip([2, 7, 11], [0, 5, 9]):
            np.testing.assert_array_equal(np.asarray(landed)[d],
                                          np.asarray(pool)[s])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kv_transfer_matches_ref(dtype):
    from repro.kernels.kv_gather import kv_transfer, kv_transfer_ref
    src = jax.random.normal(jax.random.PRNGKey(0), (10, 2, 2, 32), dtype)
    dst = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 2, 32), dtype)
    sp = jnp.asarray([0, 13, 7, 39, 22], jnp.int32)   # flat page ids
    dp = jnp.asarray([31, 2, 17, 9, 0], jnp.int32)
    out = kv_transfer(src, dst, sp, dp)
    ref = kv_transfer_ref(src, dst, sp, dp)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))
    # untouched pages preserved
    flat = np.asarray(out, np.float32).reshape(-1, 32)
    dflat = np.asarray(dst, np.float32).reshape(-1, 32)
    untouched = [i for i in range(dflat.shape[0]) if i not in [31, 2, 17, 9, 0]]
    np.testing.assert_array_equal(flat[untouched], dflat[untouched])
